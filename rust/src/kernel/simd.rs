//! Lane-fused SIMD micro-kernels: the tile → panel → lane hierarchy.
//!
//! This module is the software mirror of SPADE's lane-fused SIMD
//! datapath (§II): one set of submodules — here, one hierarchical loop
//! structure — shared by all three precisions instead of three
//! unrelated inner loops. The hierarchy, top to bottom:
//!
//! * **Tile** — a row block handed to one worker by the work-stealing
//!   queue ([`super::pool::RowQueue`]); every precision enters through
//!   the same tile contract (disjoint output rows, shared read-only
//!   operand plans).
//! * **Panel** — a B-column strip sized for cache residency
//!   ([`TileConfig::p16_panel`] / [`TileConfig::p32_panel`]): the
//!   k-deep slice of B touched by the inner loops stays hot while the
//!   tile's rows stream over it, instead of re-streaming all of B per
//!   output row.
//! * **k-chunk** — reductions deeper than [`TileConfig::k_chunk_for`]
//!   stream A (and the matching B slice) in L2-sized chunks with
//!   exact partial `i64`/`i128`/quire accumulation per chunk; deep
//!   P16 additionally folds each exact `i128` chunk sum into a quire
//!   with a single `mac_raw`, paying the 512-bit walk once per chunk
//!   instead of once per MAC.
//! * **Lane** — a small fixed set of independent accumulators kept in
//!   registers: [`P8_LANES`] `i64` LUT-gather lanes for P8, a
//!   [`P16_MR`]×[`P16_NR`] `i128` register micro-tile for P16, and a
//!   panel of reused quires for P32/long-k. Lanes break the
//!   load-add-store round trip to a heap accumulator per MAC — the
//!   serial dependency chain that kept the old element-at-a-time loops
//!   scalar — so the compiler can keep the adds in vector registers.
//!
//! Bit-exactness is structural, not incidental: every accumulator is
//! an exact integer (or the exact quire), and integer addition is
//! associative, so *any* tile/panel/lane reordering produces the same
//! final sum and therefore the same single rounding. The identity
//! tests in `tests/kernel_planar.rs` hold all paths to the
//! `Backend::PositExact` oracle.
//!
//! ## Inner-loop selection
//!
//! [`InnerPath`] names the selectable loop *shapes* (lane-fused,
//! forced gather, hybrid LUT, unblocked baseline); the orthogonal
//! [`IsaBody`] axis names which hand-written instruction-set body
//! fills the P8 lane loops — portable scalar, AVX2 ymm gather,
//! AVX-512 zmm gather, or NEON — detected and ranked by
//! [`super::isa`] and swept by the autotuner. `Auto` (what
//! [`super::gemm::gemm`] uses) runs the dispatched body;
//! `Unblocked` keeps the PR-1 element-at-a-time loops as the measured
//! baseline for `benches/hotpath.rs` — see
//! [`super::gemm::gemm_single_path`].
//!
//! ## Tuning
//!
//! Panel widths and the work-stealing chunk size are runtime-tunable
//! through [`TileConfig`], carried in a
//! [`super::settings::KernelConfig`] and threaded into every inner
//! loop explicitly (the `SPADE_KERNEL_TILE` environment spec is parsed
//! once, at the process edge, by
//! [`crate::api::EngineConfig::from_env`] — the kernel itself never
//! reads the environment). Lane counts are compile-time constants:
//! they size on-stack accumulator arrays.

use crate::posit::{decode, PositClass, PositFormat, Quire};

use super::gemm::{activate_words, encode_acc_i128, encode_acc_i64,
                  Activation};
use super::isa::{self, IsaBody};
use super::lut::{self, P16_ACC_FRAC_OFFSET, P8_ACC_FRAC_OFFSET};
use super::plan::DecodedPlan;

/// P8 lane width: output columns accumulated per register-resident
/// lane block. Eight `i64` lanes fill two 256-bit vector registers.
pub const P8_LANES: usize = 8;

/// P16 micro-tile rows: output rows sharing one load of each B
/// element (B traffic drops by this factor versus row-at-a-time).
pub const P16_MR: usize = 4;

/// P16 micro-tile columns: `i128` accumulator lanes per row of the
/// register micro-tile.
pub const P16_NR: usize = 4;

/// Which inner-loop body a GEMM runs. [`super::gemm::gemm`] always
/// uses `Auto`; the others exist so benches and identity tests can pin
/// a specific body ([`super::gemm::gemm_single_path`]) — except
/// `Hybrid`, which the autotuner may also select for P16 when its
/// probe shows the bucketed product LUT actually pays (≥ 1.1x).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum InnerPath {
    /// Lane-fused loops, AVX2 LUT-gather for P8 when the CPU has it.
    Auto,
    /// Lane-fused loops, portable Rust only (no `std::arch`).
    Portable,
    /// Force the AVX2 LUT-gather P8 loop (other formats fall back to
    /// the lane-fused loops). Unavailable off x86_64/AVX2.
    Gather,
    /// P16 runs the scale-bucketed hybrid product LUT
    /// ([`lut::p16_hyb_mul`]) inside the blocked micro-tile; exact
    /// multiply off-bucket, so results are bit-identical to `Auto`.
    /// **Default-off**: only the autotuner (with its ≥ 1.1x margin) or
    /// an explicit pin selects it. Other formats fall back to the
    /// lane-fused loops.
    Hybrid,
    /// The PR-1 element-at-a-time loops — scalar LUT gather for P8,
    /// unblocked P16, full-width quire row for P32. Kept as the bench
    /// baseline (`simd_vs_scalar_gather`, `blocked_vs_unblocked_p16`).
    Unblocked,
}

impl InnerPath {
    /// Stable string tag shared by the config grammar
    /// (`SPADE_KERNEL_PATH`) and the persisted tuned-table schema.
    pub fn tag(self) -> &'static str {
        match self {
            InnerPath::Auto => "auto",
            InnerPath::Portable => "portable",
            InnerPath::Gather => "gather",
            InnerPath::Hybrid => "hybrid",
            InnerPath::Unblocked => "unblocked",
        }
    }

    /// Inverse of [`tag`](Self::tag); strict (unknown tags are an
    /// error naming the grammar).
    pub fn from_tag(s: &str) -> Result<InnerPath, String> {
        match s {
            "auto" => Ok(InnerPath::Auto),
            "portable" => Ok(InnerPath::Portable),
            "gather" => Ok(InnerPath::Gather),
            "hybrid" => Ok(InnerPath::Hybrid),
            "unblocked" => Ok(InnerPath::Unblocked),
            other => Err(format!(
                "unknown inner path {other:?} (expected auto, \
                 portable, gather, hybrid, or unblocked)")),
        }
    }
}

/// Runtime-tunable tile parameters. Defaults suit ~32 KiB L1d;
/// overrides arrive either as typed fields (builder API) or as a
/// comma-separated `key=value` spec (the `SPADE_KERNEL_TILE` format,
/// parsed **strictly** by [`TileConfig::parse`]):
///
/// ```text
/// p16_panel=48,p32_panel=16,steal_rows=2
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct TileConfig {
    /// B-column panel width for the blocked P16 path (must be at
    /// least [`P16_NR`]). Default 64: a 256-deep panel of planar
    /// sig+w columns stays L2-resident across the tile's rows.
    pub p16_panel: usize,
    /// B-column panel width (= live quire count) for the P32/long-k
    /// quire path (must be ≥ 1). Default 32.
    pub p32_panel: usize,
    /// Rows per work-stealing chunk; 0 (default) sizes chunks
    /// automatically to ~4 per worker. In a *spec string* the key is
    /// only accepted with a value ≥ 1 — omit it for automatic sizing.
    pub steal_rows: usize,
    /// Reduction-depth chunk for the streaming k-chunked loops: a
    /// GEMM whose k exceeds this streams A (and the matching B slice)
    /// in k-chunks of this many elements, with exact partial
    /// `i64`/`i128`/quire accumulation per chunk (integer accumulators
    /// are associative, so every chunking is bit-identical to the
    /// unchunked loop). 0 (default) = automatic: chunk by
    /// [`K_CHUNK_DEFAULT`] once k exceeds [`K_CHUNK_AUTO`]. In a
    /// *spec string* the key is only accepted with a value ≥ 1 — omit
    /// it for automatic sizing.
    pub k_chunk: usize,
}

impl TileConfig {
    /// The built-in defaults (const so statics can embed them).
    pub const DEFAULT: TileConfig = TileConfig {
        p16_panel: 64,
        p32_panel: 32,
        steal_rows: 0,
        k_chunk: 0,
    };

    /// Parse an override spec (the `SPADE_KERNEL_TILE` format),
    /// **rejecting** anything suspicious instead of silently fixing
    /// it: unknown keys, fragments without `=`, unparsable or
    /// overflowing numbers, zero panels, panels below the lane
    /// minimums, and an explicit `steal_rows=0` are all hard errors —
    /// a typo'd tuning spec should fail engine construction loudly,
    /// not quietly run with defaults (the pre-PR-4 parser clamped and
    /// ignored; `EngineConfig` validation surfaces these messages).
    ///
    /// An empty spec yields the defaults.
    pub fn parse(spec: &str) -> Result<TileConfig, String> {
        let mut cfg = TileConfig::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue; // tolerate trailing / doubled commas only
            }
            let Some((key, val)) = part.split_once('=') else {
                return Err(format!(
                    "tile spec fragment {part:?} is not key=value"));
            };
            let (key, val) = (key.trim(), val.trim());
            let v: usize = val.parse().map_err(|_| {
                format!("tile spec {key}={val:?}: not a valid count \
                         (unparsable or overflows usize)")
            })?;
            match key {
                "p16_panel" => cfg.p16_panel = v,
                "p32_panel" => cfg.p32_panel = v,
                "steal_rows" => {
                    if v == 0 {
                        return Err("tile spec steal_rows=0: chunks \
                                    must be at least one row (omit \
                                    the key for automatic sizing)"
                            .into());
                    }
                    cfg.steal_rows = v;
                }
                "k_chunk" => {
                    if v == 0 {
                        return Err("tile spec k_chunk=0: a reduction \
                                    chunk must cover at least one \
                                    element (omit the key for \
                                    automatic sizing)"
                            .into());
                    }
                    cfg.k_chunk = v;
                }
                _ => {
                    return Err(format!(
                        "tile spec has unknown key {key:?} (expected \
                         p16_panel, p32_panel, steal_rows or \
                         k_chunk)"));
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Check field ranges (also enforced by [`TileConfig::parse`] and
    /// by `EngineConfig::validate` for builder-set values): panels
    /// must cover at least one lane block.
    pub fn validate(&self) -> Result<(), String> {
        if self.p16_panel < P16_NR {
            return Err(format!(
                "p16_panel={} is below the {P16_NR}-lane micro-tile \
                 minimum", self.p16_panel));
        }
        if self.p32_panel == 0 {
            return Err("p32_panel=0: the quire panel needs at least \
                        one column".into());
        }
        Ok(())
    }

    /// The k-chunk to stream a depth-`k` reduction with, or `None`
    /// when the whole reduction runs unchunked. An explicit
    /// [`TileConfig::k_chunk`] engages exactly when `k` exceeds it;
    /// the automatic default engages past [`K_CHUNK_AUTO`] with
    /// [`K_CHUNK_DEFAULT`]-deep chunks.
    pub fn k_chunk_for(&self, k: usize) -> Option<usize> {
        if self.k_chunk > 0 {
            (k > self.k_chunk).then_some(self.k_chunk)
        } else {
            (k > K_CHUNK_AUTO).then_some(K_CHUNK_DEFAULT)
        }
    }
}

/// Reduction depth past which the automatic heuristic starts
/// streaming A in k-chunks: below this the whole B slice a tile walks
/// comfortably outlives one pass through the rows.
pub const K_CHUNK_AUTO: usize = 1024;

/// Automatic k-chunk depth: 512 elements keeps a default-width B
/// k-slice (512 × 64 planar sig+w columns ≈ 384 KiB at P16) within
/// reach of L2 while the tile's rows re-walk it.
pub const K_CHUNK_DEFAULT: usize = 512;

impl Default for TileConfig {
    fn default() -> TileConfig {
        TileConfig::DEFAULT
    }
}

/// True when the `std::arch` AVX2 LUT-gather P8 loop can run on this
/// machine (always false off x86_64). Thin alias over the central
/// detection in [`super::isa`] — kept because the `Gather` pin and
/// its config validation predate the body axis.
pub fn gather_available() -> bool {
    isa::host_has(IsaBody::Avx2)
}

/// Bias row decoded once into planar fields (shared by every inner
/// loop; built by the GEMM front end in [`super::gemm`]).
pub(super) struct BiasDec {
    pub(super) sig: Vec<i64>,
    pub(super) w: Vec<i32>,
    pub(super) nar: Vec<bool>,
    pub(super) has_nar: bool,
}

impl BiasDec {
    pub(super) fn new(words: &[u64], fmt: PositFormat) -> BiasDec {
        let p = DecodedPlan::from_words(words.to_vec(), 1, words.len(),
                                        fmt);
        let has_nar = p.has_nar;
        // `nar` is only read when `has_nar` (it is empty otherwise).
        BiasDec { sig: p.sig, w: p.w, nar: p.nar_cols, has_nar }
    }
}

/// Fused-epilogue finish of one **cache-hot** output window: the
/// word-level activation clamp on the freshly rounded words, then
/// planar field emission (`sig`/`w`, plus the packed byte copy for
/// ≤8-bit formats) — exactly the decode the next layer would otherwise
/// pay through [`DecodedPlan::from_words`], done while the window is
/// still in L1/L2 right after [`gemm_rows`] filled it.
///
/// The caller guarantees no NaR can appear in `words`: the kernel's
/// rounding ([`super::gemm::encode_acc_i64`] and friends) saturates to
/// maxpos and never overflows to NaR, so NaR outputs arise only from
/// NaR operands — which [`super::gemm::gemm_fused_into`] routes to the
/// masked slow path instead of here. That is what lets this loop skip
/// mask building entirely.
pub(super) fn epilogue_window(fmt: PositFormat, act: Activation,
                              words: &mut [u64], sig: &mut [i64],
                              w: &mut [i32],
                              w8: Option<&mut [u8]>) {
    debug_assert_eq!(words.len(), sig.len());
    debug_assert_eq!(words.len(), w.len());
    let nar = fmt.nar();
    // One shared activation implementation (`gemm::activate_words`)
    // for the fused and layerwise paths — the bit-identity contract
    // between them is then structural, not a parallel-maintenance
    // promise.
    activate_words(words, act, fmt);
    if fmt == crate::posit::P8_FMT || fmt == crate::posit::P16_FMT {
        let t = if fmt == crate::posit::P8_FMT {
            lut::p8_decode_lut()
        } else {
            lut::p16_decode_lut()
        };
        for (i, &wd) in words.iter().enumerate() {
            let e = &t[wd as usize];
            debug_assert!(!e.nar, "NaR output without NaR operand");
            sig[i] = e.sig as i64;
            w[i] = e.w as i32;
        }
    } else {
        for (i, &wd) in words.iter().enumerate() {
            debug_assert_ne!(wd, nar,
                             "NaR output without NaR operand");
            let d = decode(wd, fmt);
            match d.class {
                PositClass::Zero | PositClass::NaR => {
                    sig[i] = 0;
                    w[i] = 0;
                }
                PositClass::Normal => {
                    let s = d.significand() as i64;
                    sig[i] = if d.sign { -s } else { s };
                    w[i] = d.scale - d.fbits as i32;
                }
            }
        }
    }
    if let Some(w8) = w8 {
        for (dst, &wd) in w8.iter_mut().zip(words.iter()) {
            *dst = wd as u8;
        }
    }
}

/// Compute output rows `i0 ..` into `out` (a whole-rows slice) with
/// the requested inner-loop body and tile geometry — the tile entry
/// point every precision shares. The LUT / fixed-offset fast paths are
/// specific to the exact standard formats; anything else goes through
/// the generic quire path (correct for any posit(n, es) the crate
/// supports). Reductions deeper than the tile's k-chunk threshold
/// ([`TileConfig::k_chunk_for`]) stream A (and the matching B slice)
/// chunk by chunk with exact partial accumulation — bit-identical by
/// associativity, asserted in `tests/kernel_kchunk.rs`.
pub(super) fn gemm_rows(a: &DecodedPlan, b: &DecodedPlan,
                        bias: Option<&BiasDec>, i0: usize,
                        out: &mut [u64], path: InnerPath,
                        body: IsaBody, tile: TileConfig) {
    let n = b.cols;
    let k = a.cols;
    let nrows = out.len() / n;
    let kc = tile.k_chunk_for(k);
    if a.fmt == crate::posit::P8_FMT {
        // Deep-k chunking streams A in L2-sized slices; since PR 10
        // the chunked loop has its own SIMD bodies (the AVX2 variant
        // of the lane block), so `Auto` chunks too — the gather
        // upgrade and the chunking compose instead of excluding each
        // other. Only the pinned baselines (`Unblocked`, `Gather`)
        // keep their unchunked shape.
        let chunkable =
            !matches!(path, InnerPath::Unblocked | InnerPath::Gather);
        if chunkable {
            if let Some(kc) = kc {
                return rows_p8_kchunk(a, b, bias, i0, nrows, out, kc,
                                      body);
            }
        }
        rows_p8(a, b, bias, i0, nrows, out, path, body);
    } else if a.fmt == crate::posit::P16_FMT {
        if path == InnerPath::Unblocked {
            if k <= lut::P16_CHUNK {
                rows_p16_unblocked(a, b, bias, i0, nrows, out);
            } else {
                rows_quire_unblocked(a, b, bias, i0, nrows, out);
            }
        } else if k > lut::P16_CHUNK {
            // Deep P16: i128 partial chunks folded into quires — the
            // PDPU-style fused accumulation replacing the per-MAC
            // quire walk the pre-chunking kernel used here.
            rows_p16_deepk(a, b, bias, i0, nrows, out, tile, kc);
        } else if let Some(kc) = kc {
            // The hybrid multiply composes with chunking: both paths
            // share the chunked micro-tile body via `mul`.
            if path == InnerPath::Hybrid {
                rows_p16_kchunk(a, b, bias, i0, nrows, out, tile, kc,
                                lut::p16_hyb_mul);
            } else {
                rows_p16_kchunk(a, b, bias, i0, nrows, out, tile, kc,
                                |sa, sb| sa * sb);
            }
        } else if path == InnerPath::Hybrid {
            rows_p16_hybrid(a, b, bias, i0, nrows, out, tile);
        } else {
            rows_p16_blocked(a, b, bias, i0, nrows, out, tile);
        }
    } else if path == InnerPath::Unblocked {
        rows_quire_unblocked(a, b, bias, i0, nrows, out);
    } else if let Some(kc) = kc {
        rows_quire_kchunk(a, b, bias, i0, nrows, out, tile, kc);
    } else {
        rows_quire_panel(a, b, bias, i0, nrows, out, tile);
    }
}

/// Bias contribution at column `j` in the P8 accumulator's fixed
/// point (0 without a bias).
#[inline]
fn p8_bias_term(bias: Option<&BiasDec>, j: usize) -> i64 {
    match bias {
        Some(bd) => bd.sig[j] << (bd.w[j] + P8_ACC_FRAC_OFFSET as i32),
        None => 0,
    }
}

/// P8 dispatch: unblocked baseline, or the lane loop filled with the
/// requested [`IsaBody`]. The path pins dominate the body axis —
/// `Gather` means "the AVX2 body, specifically" and `Portable` means
/// "no `std::arch` at all" (the old `SPADE_KERNEL_GATHER=0` kill
/// switch) — and every ISA body is availability-checked here, right
/// before the one `unsafe` call that needs it.
#[allow(unused_variables)] // `body` is fully consumed only on x86_64/aarch64
fn rows_p8(a: &DecodedPlan, b: &DecodedPlan, bias: Option<&BiasDec>,
           i0: usize, nrows: usize, out: &mut [u64], path: InnerPath,
           body: IsaBody) {
    if path == InnerPath::Unblocked {
        return rows_p8_unblocked(a, b, bias, i0, nrows, out);
    }
    let body = match path {
        InnerPath::Gather => IsaBody::Avx2,
        InnerPath::Portable => IsaBody::Portable,
        _ => body,
    };
    #[cfg(all(target_arch = "x86_64", spade_avx512))]
    if body == IsaBody::Avx512 && isa::host_has(IsaBody::Avx512) {
        // SAFETY: AVX-512F presence was just runtime-checked.
        unsafe { rows_p8_avx512(a, b, bias, i0, nrows, out) };
        return;
    }
    #[cfg(target_arch = "x86_64")]
    if matches!(body, IsaBody::Avx2 | IsaBody::Avx512)
        && isa::host_has(IsaBody::Avx2)
    {
        // An AVX-512 request on a host without it (or without the
        // compiled-in body) degrades to the ymm gather, then scalar.
        // SAFETY: AVX2 presence was just runtime-checked.
        unsafe { rows_p8_avx2(a, b, bias, i0, nrows, out) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if body == IsaBody::Neon && isa::host_has(IsaBody::Neon) {
        // SAFETY: NEON (ASIMD) is architecturally mandatory on
        // aarch64, and `host_has` confirms it.
        unsafe { rows_p8_neon(a, b, bias, i0, nrows, out) };
        return;
    }
    rows_p8_lanes(a, b, bias, i0, nrows, out)
}

/// Lane accumulators seeded with the bias terms for columns
/// `j0 .. j0 + P8_LANES` (shared by the portable and AVX2 bodies).
#[inline]
fn p8_lane_bias(bias: Option<&BiasDec>, j0: usize) -> [i64; P8_LANES] {
    let mut lanes = [0i64; P8_LANES];
    for (l, slot) in lanes.iter_mut().enumerate() {
        *slot = p8_bias_term(bias, j0 + l);
    }
    lanes
}

/// Scalar tail for the columns past the last full lane block — one
/// shared copy so the portable and AVX2 bodies cannot diverge.
#[inline]
fn p8_tail(arow: &[u8], b8: &[u8], bias: Option<&BiasDec>, j0: usize,
           n: usize, fmt: PositFormat, orow: &mut [u64]) {
    let lut = lut::p8_prod_lut();
    for j in j0..n {
        let mut acc = p8_bias_term(bias, j);
        for (kk, &aw) in arow.iter().enumerate() {
            if aw != 0 {
                acc +=
                    lut[((aw as usize) << 8) | b8[kk * n + j] as usize];
            }
        }
        orow[j] = encode_acc_i64(acc, P8_ACC_FRAC_OFFSET, fmt);
    }
}

/// One register-resident lane block: accumulate `arow`'s exact
/// LUT products for columns `j0 .. j0 + P8_LANES` into `lanes`.
/// `k0` offsets the B row index (nonzero when a k-chunk walk hands
/// in a sub-slice of A). One shared copy feeds the portable lane
/// loop, the chunked loop, and the AVX-512 body's 8-wide remainder —
/// divergence between them is structurally impossible.
#[inline]
fn p8_lane_block(arow: &[u8], b8: &[u8], n: usize, k0: usize,
                 j0: usize, lanes: &mut [i64; P8_LANES]) {
    let lut = lut::p8_prod_lut();
    for (kk, &aw) in arow.iter().enumerate() {
        if aw == 0 {
            continue;
        }
        let base = (aw as usize) << 8;
        let row = (k0 + kk) * n + j0;
        let brow = &b8[row..row + P8_LANES];
        for (slot, &bw) in lanes.iter_mut().zip(brow) {
            *slot += lut[base | bw as usize];
        }
    }
}

/// P8 lane-fused portable loop: [`P8_LANES`] independent `i64`
/// accumulators walk the k dimension together, one exact-product LUT
/// gather per lane per step. The lanes live in a fixed array the
/// compiler keeps in vector registers, so the per-MAC cost is one
/// gather + one add — no accumulator load/store round trip.
fn rows_p8_lanes(a: &DecodedPlan, b: &DecodedPlan,
                 bias: Option<&BiasDec>, i0: usize, nrows: usize,
                 out: &mut [u64]) {
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let (a8, b8) = (&a.words8, &b.words8);
    for r in 0..nrows {
        let i = i0 + r;
        let arow = &a8[i * k..(i + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        let mut j0 = 0usize;
        while j0 + P8_LANES <= n {
            let mut lanes = p8_lane_bias(bias, j0);
            p8_lane_block(arow, b8, n, 0, j0, &mut lanes);
            for (jj, &v) in lanes.iter().enumerate() {
                orow[j0 + jj] =
                    encode_acc_i64(v, P8_ACC_FRAC_OFFSET, fmt);
            }
            j0 += P8_LANES;
        }
        p8_tail(arow, b8, bias, j0, n, fmt, orow);
    }
}

/// P8 AVX2 loop: same lane structure as [`rows_p8_lanes`], with the
/// eight LUT gathers per step issued as two `vpgatherqq` instructions
/// and the lane adds as two `vpaddq` — the literal hardware gather the
/// portable loop autovectorizes toward. Bit-identical by construction
/// (same integer sums); `tests/kernel_planar.rs` asserts it.
///
/// # Safety
/// The caller must have verified AVX2 support at runtime
/// (`is_x86_feature_detected!("avx2")`) before calling — the only
/// call site, in the P8 row dispatch, does exactly that.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn rows_p8_avx2(a: &DecodedPlan, b: &DecodedPlan,
                       bias: Option<&BiasDec>, i0: usize, nrows: usize,
                       out: &mut [u64]) {
    use std::arch::x86_64::{
        __m128i, __m256i, _mm256_add_epi64, _mm256_cvtepu8_epi64,
        _mm256_i64gather_epi64, _mm256_loadu_si256, _mm256_or_si256,
        _mm256_set1_epi64x, _mm256_storeu_si256, _mm_cvtsi32_si128,
    };
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let lut = lut::p8_prod_lut();
    let lp = lut.as_ptr();
    let (a8, b8) = (&a.words8, &b.words8);
    for r in 0..nrows {
        let i = i0 + r;
        let arow = &a8[i * k..(i + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        let mut j0 = 0usize;
        while j0 + P8_LANES <= n {
            let mut lanes = p8_lane_bias(bias, j0);
            let mut vlo =
                _mm256_loadu_si256(lanes.as_ptr() as *const __m256i);
            let mut vhi = _mm256_loadu_si256(
                lanes.as_ptr().add(4) as *const __m256i);
            for (kk, &aw) in arow.iter().enumerate() {
                if aw == 0 {
                    continue;
                }
                let base = _mm256_set1_epi64x((aw as i64) << 8);
                let bytes: [u8; 8] = b8
                    [kk * n + j0..kk * n + j0 + P8_LANES]
                    .try_into()
                    .unwrap();
                let bv = u64::from_le_bytes(bytes);
                // Zero-extend 4 B words at a time into i64 index
                // lanes, OR in the A word's LUT row base, gather.
                let lo: __m128i = _mm_cvtsi32_si128(bv as u32 as i32);
                let hi: __m128i =
                    _mm_cvtsi32_si128((bv >> 32) as u32 as i32);
                let ilo = _mm256_or_si256(_mm256_cvtepu8_epi64(lo),
                                          base);
                let ihi = _mm256_or_si256(_mm256_cvtepu8_epi64(hi),
                                          base);
                vlo = _mm256_add_epi64(
                    vlo, _mm256_i64gather_epi64::<8>(lp, ilo));
                vhi = _mm256_add_epi64(
                    vhi, _mm256_i64gather_epi64::<8>(lp, ihi));
            }
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i,
                                vlo);
            _mm256_storeu_si256(
                lanes.as_mut_ptr().add(4) as *mut __m256i, vhi);
            for (jj, &v) in lanes.iter().enumerate() {
                orow[j0 + jj] =
                    encode_acc_i64(v, P8_ACC_FRAC_OFFSET, fmt);
            }
            j0 += P8_LANES;
        }
        p8_tail(arow, b8, bias, j0, n, fmt, orow);
    }
}

/// P8 AVX-512 loop: the gather body widened to 16 lanes per step —
/// two zmm accumulators, each fed by a `vpmovzxbq`-extended half of a
/// 16-byte B slice OR'd with the A word's LUT-row base and one zmm
/// `vpgatherqq`. After the 16-wide loop an 8-wide block runs through
/// the shared [`p8_lane_block`], then the shared scalar tail —
/// identical integer sums, so bit-identical by associativity (the
/// forced-body sweep in `tests/isa_bodies.rs` asserts it against the
/// quire oracle). Compiled only when `build.rs` finds a toolchain
/// with stable AVX-512 support (`spade_avx512`).
///
/// # Safety
/// The caller must have verified AVX-512F support at runtime
/// (`isa::host_has(IsaBody::Avx512)`) before calling — the only call
/// site, in the P8 row dispatch, does exactly that.
#[cfg(all(target_arch = "x86_64", spade_avx512))]
#[target_feature(enable = "avx512f")]
unsafe fn rows_p8_avx512(a: &DecodedPlan, b: &DecodedPlan,
                         bias: Option<&BiasDec>, i0: usize,
                         nrows: usize, out: &mut [u64]) {
    use std::arch::x86_64::{
        __m512i, _mm512_add_epi64, _mm512_cvtepu8_epi64,
        _mm512_i64gather_epi64, _mm512_or_si512, _mm512_set1_epi64,
        _mm_cvtsi64_si128,
    };
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let lut = lut::p8_prod_lut();
    let lp = lut.as_ptr() as *const u8;
    let (a8, b8) = (&a.words8, &b.words8);
    const W: usize = 2 * P8_LANES;
    for r in 0..nrows {
        let i = i0 + r;
        let arow = &a8[i * k..(i + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        let mut j0 = 0usize;
        while j0 + W <= n {
            // `[i64; P8_LANES]` and `__m512i` are both 64 bytes, so
            // the bias-seeded lane arrays transmute straight into the
            // accumulator registers (and back out below) — no
            // load/store intrinsic whose signature drifted across
            // toolchains.
            let mut vlo: __m512i =
                core::mem::transmute(p8_lane_bias(bias, j0));
            let mut vhi: __m512i =
                core::mem::transmute(p8_lane_bias(bias, j0 + P8_LANES));
            for (kk, &aw) in arow.iter().enumerate() {
                if aw == 0 {
                    continue;
                }
                let base = _mm512_set1_epi64((aw as i64) << 8);
                let row = kk * n + j0;
                let blo = u64::from_le_bytes(
                    b8[row..row + 8].try_into().unwrap());
                let bhi = u64::from_le_bytes(
                    b8[row + 8..row + 16].try_into().unwrap());
                let ilo = _mm512_or_si512(
                    _mm512_cvtepu8_epi64(_mm_cvtsi64_si128(blo as i64)),
                    base);
                let ihi = _mm512_or_si512(
                    _mm512_cvtepu8_epi64(_mm_cvtsi64_si128(bhi as i64)),
                    base);
                vlo = _mm512_add_epi64(
                    vlo, _mm512_i64gather_epi64::<8>(ilo, lp));
                vhi = _mm512_add_epi64(
                    vhi, _mm512_i64gather_epi64::<8>(ihi, lp));
            }
            let lo: [i64; P8_LANES] = core::mem::transmute(vlo);
            let hi: [i64; P8_LANES] = core::mem::transmute(vhi);
            for (jj, &v) in lo.iter().chain(hi.iter()).enumerate() {
                orow[j0 + jj] =
                    encode_acc_i64(v, P8_ACC_FRAC_OFFSET, fmt);
            }
            j0 += W;
        }
        while j0 + P8_LANES <= n {
            let mut lanes = p8_lane_bias(bias, j0);
            p8_lane_block(arow, b8, n, 0, j0, &mut lanes);
            for (jj, &v) in lanes.iter().enumerate() {
                orow[j0 + jj] =
                    encode_acc_i64(v, P8_ACC_FRAC_OFFSET, fmt);
            }
            j0 += P8_LANES;
        }
        p8_tail(arow, b8, bias, j0, n, fmt, orow);
    }
}

/// P8 NEON body: the eight `i64` lanes held in four 128-bit
/// `int64x2_t` registers. NEON has no 64-bit gather instruction, so
/// the product-LUT reads stay scalar (the 64 KiB table is
/// cache-resident); what the body makes explicit is the lane *adds* —
/// `vaddq_s64` pairs — the serial chain the portable loop leaves to
/// the autovectorizer. Same integer sums, same single rounding:
/// bit-identical to the scalar quire oracle by associativity.
///
/// # Safety
/// The caller must have confirmed NEON via
/// `isa::host_has(IsaBody::Neon)` — trivially true on aarch64, where
/// ASIMD is architecturally mandatory, but the dispatch checks anyway
/// so every body crosses the same guarded gate.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn rows_p8_neon(a: &DecodedPlan, b: &DecodedPlan,
                       bias: Option<&BiasDec>, i0: usize, nrows: usize,
                       out: &mut [u64]) {
    use core::arch::aarch64::{
        vaddq_s64, vcombine_s64, vcreate_s64, vld1q_s64, vst1q_s64,
    };
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let lut = lut::p8_prod_lut();
    let (a8, b8) = (&a.words8, &b.words8);
    for r in 0..nrows {
        let i = i0 + r;
        let arow = &a8[i * k..(i + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        let mut j0 = 0usize;
        while j0 + P8_LANES <= n {
            let seed = p8_lane_bias(bias, j0);
            let sp = seed.as_ptr();
            let mut v0 = vld1q_s64(sp);
            let mut v1 = vld1q_s64(sp.add(2));
            let mut v2 = vld1q_s64(sp.add(4));
            let mut v3 = vld1q_s64(sp.add(6));
            for (kk, &aw) in arow.iter().enumerate() {
                if aw == 0 {
                    continue;
                }
                let base = (aw as usize) << 8;
                let brow = &b8[kk * n + j0..kk * n + j0 + P8_LANES];
                let p0 = vcombine_s64(
                    vcreate_s64(lut[base | brow[0] as usize] as u64),
                    vcreate_s64(lut[base | brow[1] as usize] as u64));
                let p1 = vcombine_s64(
                    vcreate_s64(lut[base | brow[2] as usize] as u64),
                    vcreate_s64(lut[base | brow[3] as usize] as u64));
                let p2 = vcombine_s64(
                    vcreate_s64(lut[base | brow[4] as usize] as u64),
                    vcreate_s64(lut[base | brow[5] as usize] as u64));
                let p3 = vcombine_s64(
                    vcreate_s64(lut[base | brow[6] as usize] as u64),
                    vcreate_s64(lut[base | brow[7] as usize] as u64));
                v0 = vaddq_s64(v0, p0);
                v1 = vaddq_s64(v1, p1);
                v2 = vaddq_s64(v2, p2);
                v3 = vaddq_s64(v3, p3);
            }
            let mut lanes = [0i64; P8_LANES];
            let mp = lanes.as_mut_ptr();
            vst1q_s64(mp, v0);
            vst1q_s64(mp.add(2), v1);
            vst1q_s64(mp.add(4), v2);
            vst1q_s64(mp.add(6), v3);
            for (jj, &v) in lanes.iter().enumerate() {
                orow[j0 + jj] =
                    encode_acc_i64(v, P8_ACC_FRAC_OFFSET, fmt);
            }
            j0 += P8_LANES;
        }
        p8_tail(arow, b8, bias, j0, n, fmt, orow);
    }
}

/// P8 element-at-a-time baseline (PR 1): one scalar LUT gather per MAC
/// into a heap accumulator row. Kept callable so
/// `benches/hotpath.rs`'s `simd_vs_scalar_gather` section measures the
/// lane fusion against the exact loop it replaced.
fn rows_p8_unblocked(a: &DecodedPlan, b: &DecodedPlan,
                     bias: Option<&BiasDec>, i0: usize, nrows: usize,
                     out: &mut [u64]) {
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let lut = lut::p8_prod_lut();
    let mut acc = vec![0i64; n];
    for r in 0..nrows {
        let i = i0 + r;
        match bias {
            Some(_) => {
                for (j, slot) in acc.iter_mut().enumerate() {
                    *slot = p8_bias_term(bias, j);
                }
            }
            None => acc.fill(0),
        }
        let arow = &a.words[i * k..(i + 1) * k];
        for (kk, &aw) in arow.iter().enumerate() {
            if aw == 0 {
                continue;
            }
            let base = (aw as usize) << 8;
            let brow = &b.words[kk * n..(kk + 1) * n];
            for (accj, &bw) in acc.iter_mut().zip(brow) {
                *accj += lut[base | bw as usize];
            }
        }
        for (o, &v) in out[r * n..(r + 1) * n].iter_mut().zip(&acc) {
            *o = encode_acc_i64(v, P8_ACC_FRAC_OFFSET, fmt);
        }
    }
}

/// P8 streaming k-chunked dispatch (k above the tile's chunk
/// threshold): picks the instruction-set variant of the chunked lane
/// walk — the AVX2 gather version when the body asks for (and the
/// host has) it, else the portable one. The chunked k-loop used to
/// lean entirely on autovectorization; the explicit ymm variant is
/// the PR 10 body the autotuner can now measure against it.
fn rows_p8_kchunk(a: &DecodedPlan, b: &DecodedPlan,
                  bias: Option<&BiasDec>, i0: usize, nrows: usize,
                  out: &mut [u64], kc: usize, body: IsaBody) {
    #[cfg(target_arch = "x86_64")]
    if matches!(body, IsaBody::Avx2 | IsaBody::Avx512)
        && isa::host_has(IsaBody::Avx2)
    {
        // SAFETY: AVX2 presence was just runtime-checked.
        unsafe {
            rows_p8_kchunk_avx2(a, b, bias, i0, nrows, out, kc);
        }
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = body;
    rows_p8_kchunk_lanes(a, b, bias, i0, nrows, out, kc)
}

/// Heap accumulator buffer for the chunked P8 walk (value = acc ×
/// 2^-12), bias-seeded once before the first chunk.
fn p8_chunk_acc(bias: Option<&BiasDec>, nrows: usize,
                n: usize) -> Vec<i64> {
    let mut acc = vec![0i64; nrows * n];
    if bias.is_some() {
        for row in acc.chunks_mut(n) {
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = p8_bias_term(bias, j);
            }
        }
    }
    acc
}

/// Scalar column tail of one row's chunk walk: columns past the last
/// full lane block accumulate straight into the heap buffer.
#[inline]
fn p8_chunk_tail(arow: &[u8], b8: &[u8], n: usize, k0: usize,
                 j0: usize, arow_acc: &mut [i64]) {
    let lut = lut::p8_prod_lut();
    for (j, slot) in arow_acc.iter_mut().enumerate().skip(j0) {
        let mut s = *slot;
        for (kk, &aw) in arow.iter().enumerate() {
            if aw != 0 {
                s += lut[((aw as usize) << 8)
                    | b8[(k0 + kk) * n + j] as usize];
            }
        }
        *slot = s;
    }
}

/// P8 streaming k-chunked loop, portable variant: the reduction is
/// carved into chunks of `kc` elements and the tile's rows re-walk
/// one chunk's B slice (`kc`×n bytes — L2-sized) before the next
/// chunk streams in, instead of dragging the whole k-deep B panel
/// through cache once per row. Lane accumulators persist across
/// chunks in a heap buffer (loaded into the register lane block for
/// the chunk's k-walk, stored after) — partial `i64` sums are exact
/// and associative, so the chunking is bit-identical to
/// [`rows_p8_lanes`].
fn rows_p8_kchunk_lanes(a: &DecodedPlan, b: &DecodedPlan,
                        bias: Option<&BiasDec>, i0: usize,
                        nrows: usize, out: &mut [u64], kc: usize) {
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let (a8, b8) = (&a.words8, &b.words8);
    let mut acc = p8_chunk_acc(bias, nrows, n);
    let mut k0 = 0usize;
    while k0 < k {
        let k1 = (k0 + kc).min(k);
        for r in 0..nrows {
            let i = i0 + r;
            let arow = &a8[i * k + k0..i * k + k1];
            let arow_acc = &mut acc[r * n..(r + 1) * n];
            let mut j0 = 0usize;
            while j0 + P8_LANES <= n {
                let mut lanes: [i64; P8_LANES] = arow_acc
                    [j0..j0 + P8_LANES]
                    .try_into()
                    .unwrap();
                p8_lane_block(arow, b8, n, k0, j0, &mut lanes);
                arow_acc[j0..j0 + P8_LANES].copy_from_slice(&lanes);
                j0 += P8_LANES;
            }
            p8_chunk_tail(arow, b8, n, k0, j0, arow_acc);
        }
        k0 = k1;
    }
    for (o, &v) in out.iter_mut().zip(&acc) {
        *o = encode_acc_i64(v, P8_ACC_FRAC_OFFSET, fmt);
    }
}

/// P8 streaming k-chunked loop, AVX2 variant: the same chunk walk as
/// [`rows_p8_kchunk_lanes`] with each lane block's gathers issued as
/// two `vpgatherqq` and the adds as two `vpaddq` — the explicit form
/// of what the autovectorizer was trusted to do before. Partial sums
/// are the same exact integers in the same heap buffer, so the
/// variant is bit-identical by associativity.
///
/// # Safety
/// The caller must have verified AVX2 support at runtime
/// (`isa::host_has(IsaBody::Avx2)`) before calling — the chunked
/// dispatch above does exactly that.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn rows_p8_kchunk_avx2(a: &DecodedPlan, b: &DecodedPlan,
                              bias: Option<&BiasDec>, i0: usize,
                              nrows: usize, out: &mut [u64],
                              kc: usize) {
    use std::arch::x86_64::{
        __m128i, __m256i, _mm256_add_epi64, _mm256_cvtepu8_epi64,
        _mm256_i64gather_epi64, _mm256_loadu_si256, _mm256_or_si256,
        _mm256_set1_epi64x, _mm256_storeu_si256, _mm_cvtsi32_si128,
    };
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let lut = lut::p8_prod_lut();
    let lp = lut.as_ptr();
    let (a8, b8) = (&a.words8, &b.words8);
    let mut acc = p8_chunk_acc(bias, nrows, n);
    let mut k0 = 0usize;
    while k0 < k {
        let k1 = (k0 + kc).min(k);
        for r in 0..nrows {
            let i = i0 + r;
            let arow = &a8[i * k + k0..i * k + k1];
            let arow_acc = &mut acc[r * n..(r + 1) * n];
            let mut j0 = 0usize;
            while j0 + P8_LANES <= n {
                let ap = arow_acc.as_ptr().add(j0);
                let mut vlo =
                    _mm256_loadu_si256(ap as *const __m256i);
                let mut vhi =
                    _mm256_loadu_si256(ap.add(4) as *const __m256i);
                for (kk, &aw) in arow.iter().enumerate() {
                    if aw == 0 {
                        continue;
                    }
                    let base = _mm256_set1_epi64x((aw as i64) << 8);
                    let row = (k0 + kk) * n + j0;
                    let bytes: [u8; 8] =
                        b8[row..row + P8_LANES].try_into().unwrap();
                    let bv = u64::from_le_bytes(bytes);
                    let lo: __m128i =
                        _mm_cvtsi32_si128(bv as u32 as i32);
                    let hi: __m128i =
                        _mm_cvtsi32_si128((bv >> 32) as u32 as i32);
                    let ilo = _mm256_or_si256(
                        _mm256_cvtepu8_epi64(lo), base);
                    let ihi = _mm256_or_si256(
                        _mm256_cvtepu8_epi64(hi), base);
                    vlo = _mm256_add_epi64(
                        vlo, _mm256_i64gather_epi64::<8>(lp, ilo));
                    vhi = _mm256_add_epi64(
                        vhi, _mm256_i64gather_epi64::<8>(lp, ihi));
                }
                let mp = arow_acc.as_mut_ptr().add(j0);
                _mm256_storeu_si256(mp as *mut __m256i, vlo);
                _mm256_storeu_si256(mp.add(4) as *mut __m256i, vhi);
                j0 += P8_LANES;
            }
            p8_chunk_tail(arow, b8, n, k0, j0, arow_acc);
        }
        k0 = k1;
    }
    for (o, &v) in out.iter_mut().zip(&acc) {
        *o = encode_acc_i64(v, P8_ACC_FRAC_OFFSET, fmt);
    }
}

/// P16 blocked path (k ≤ [`lut::P16_CHUNK`]): B-column panels sized by
/// [`TileConfig::p16_panel`] for cache residency, and inside each
/// panel a [`P16_MR`]×[`P16_NR`] register micro-tile of `i128`
/// accumulators — each loaded B element feeds [`P16_MR`] output rows,
/// cutting B traffic by that factor versus the row-at-a-time loop.
fn rows_p16_blocked(a: &DecodedPlan, b: &DecodedPlan,
                    bias: Option<&BiasDec>, i0: usize, nrows: usize,
                    out: &mut [u64], tile: TileConfig) {
    rows_p16_blocked_with(a, b, bias, i0, nrows, out, tile,
                          |sa, sb| sa * sb);
}

/// P16 blocked path with the scale-bucketed hybrid product LUT
/// ([`lut::p16_hyb_mul`]) substituted for the significand multiply:
/// short-fraction operand pairs (both significand magnitudes below
/// [`lut::P16_HYB_MAG`], a property the regime/exponent split of the
/// word determines) gather their exact product from a 256×256 table;
/// off-bucket pairs fall back to the exact `i64` multiply — so the
/// path is bit-identical to [`rows_p16_blocked`] by construction.
/// Selected only by an explicit [`InnerPath::Hybrid`] pin or by the
/// autotuner when its probe shows ≥ 1.1x (`p16_hybrid_lut_vs_exact`
/// in `BENCH_hotpath.json` reports the measured ratio).
fn rows_p16_hybrid(a: &DecodedPlan, b: &DecodedPlan,
                   bias: Option<&BiasDec>, i0: usize, nrows: usize,
                   out: &mut [u64], tile: TileConfig) {
    rows_p16_blocked_with(a, b, bias, i0, nrows, out, tile,
                          lut::p16_hyb_mul);
}

/// Shared body of the P16 blocked paths; `mul` is the significand
/// product (exact multiply, or the hybrid LUT with exact fallback —
/// both return the exact product, so the caller choice cannot change
/// results).
#[allow(clippy::too_many_arguments)]
fn rows_p16_blocked_with(a: &DecodedPlan, b: &DecodedPlan,
                         bias: Option<&BiasDec>, i0: usize,
                         nrows: usize, out: &mut [u64],
                         tile: TileConfig,
                         mul: impl Fn(i64, i64) -> i64) {
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let off = P16_ACC_FRAC_OFFSET as i32;
    let panel = tile.p16_panel.max(P16_NR);
    let mut j0 = 0usize;
    while j0 < n {
        let jend = (j0 + panel).min(n);
        let mut r = 0usize;
        while r < nrows {
            let iw = (nrows - r).min(P16_MR);
            let mut j = j0;
            while j < jend {
                let jw = (jend - j).min(P16_NR);
                let mut acc = [[0i128; P16_NR]; P16_MR];
                if let Some(bd) = bias {
                    for row in acc.iter_mut().take(iw) {
                        for (ni, slot) in
                            row.iter_mut().enumerate().take(jw)
                        {
                            *slot = (bd.sig[j + ni] as i128)
                                << (bd.w[j + ni] + off);
                        }
                    }
                }
                for kk in 0..k {
                    let bs = &b.sig[kk * n + j..kk * n + j + jw];
                    let bw = &b.w[kk * n + j..kk * n + j + jw];
                    for (mi, arow_acc) in
                        acc.iter_mut().enumerate().take(iw)
                    {
                        let idx = (i0 + r + mi) * k + kk;
                        let sa = a.sig[idx];
                        if sa == 0 {
                            continue;
                        }
                        let wa = a.w[idx];
                        for ni in 0..jw {
                            let p = mul(sa, bs[ni]);
                            if p != 0 {
                                arow_acc[ni] +=
                                    (p as i128) << (wa + bw[ni] + off);
                            }
                        }
                    }
                }
                for (mi, arow_acc) in acc.iter().enumerate().take(iw) {
                    for (ni, &v) in
                        arow_acc.iter().enumerate().take(jw)
                    {
                        out[(r + mi) * n + j + ni] = encode_acc_i128(
                            v, P16_ACC_FRAC_OFFSET, fmt);
                    }
                }
                j += jw;
            }
            r += iw;
        }
        j0 = jend;
    }
}

/// P16 streaming k-chunked loop (k above the chunk threshold but
/// within the `i128` headroom): the register micro-tile of
/// [`rows_p16_blocked`] runs chunk by chunk over the reduction, with
/// the accumulators persisted in a heap buffer between chunks (loaded
/// into the register tile for the chunk's k-walk, stored after).
/// Each chunk's B slice (`kc`×panel planar columns) stays L2-resident
/// while every micro-tile of the row block walks it. Partial `i128`
/// sums are exact and associative → bit-identical to the unchunked
/// loop. `mul` is the significand product (exact, or the hybrid LUT
/// with exact fallback — see [`rows_p16_blocked_with`]), so
/// [`InnerPath::Hybrid`] composes with chunking.
#[allow(clippy::too_many_arguments)]
fn rows_p16_kchunk(a: &DecodedPlan, b: &DecodedPlan,
                   bias: Option<&BiasDec>, i0: usize, nrows: usize,
                   out: &mut [u64], tile: TileConfig, kc: usize,
                   mul: impl Fn(i64, i64) -> i64) {
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let off = P16_ACC_FRAC_OFFSET as i32;
    let panel = tile.p16_panel.max(P16_NR);
    // Persistent accumulators (value = acc * 2^-56), bias-seeded once.
    let mut accbuf = vec![0i128; nrows * n];
    if let Some(bd) = bias {
        for row in accbuf.chunks_mut(n) {
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = (bd.sig[j] as i128) << (bd.w[j] + off);
            }
        }
    }
    let mut k0 = 0usize;
    while k0 < k {
        let k1 = (k0 + kc).min(k);
        let mut j0 = 0usize;
        while j0 < n {
            let jend = (j0 + panel).min(n);
            let mut r = 0usize;
            while r < nrows {
                let iw = (nrows - r).min(P16_MR);
                let mut j = j0;
                while j < jend {
                    let jw = (jend - j).min(P16_NR);
                    let mut acc = [[0i128; P16_NR]; P16_MR];
                    for (mi, row) in
                        acc.iter_mut().enumerate().take(iw)
                    {
                        row[..jw].copy_from_slice(
                            &accbuf[(r + mi) * n + j
                                ..(r + mi) * n + j + jw]);
                    }
                    for kk in k0..k1 {
                        let bs = &b.sig[kk * n + j..kk * n + j + jw];
                        let bw = &b.w[kk * n + j..kk * n + j + jw];
                        for (mi, arow_acc) in
                            acc.iter_mut().enumerate().take(iw)
                        {
                            let idx = (i0 + r + mi) * k + kk;
                            let sa = a.sig[idx];
                            if sa == 0 {
                                continue;
                            }
                            let wa = a.w[idx];
                            for ni in 0..jw {
                                let p = mul(sa, bs[ni]);
                                if p != 0 {
                                    arow_acc[ni] += (p as i128)
                                        << (wa + bw[ni] + off);
                                }
                            }
                        }
                    }
                    for (mi, row) in
                        acc.iter().enumerate().take(iw)
                    {
                        accbuf[(r + mi) * n + j
                            ..(r + mi) * n + j + jw]
                            .copy_from_slice(&row[..jw]);
                    }
                    j += jw;
                }
                r += iw;
            }
            j0 = jend;
        }
        k0 = k1;
    }
    for (o, &v) in out.iter_mut().zip(&accbuf) {
        *o = encode_acc_i128(v, P16_ACC_FRAC_OFFSET, fmt);
    }
}

/// P16 deep-reduction loop (k beyond [`lut::P16_CHUNK`]): the
/// reduction is carved into chunks that fit the `i128` headroom, each
/// chunk accumulates at full micro-loop speed in `i128` fixed point,
/// and the exact partial sum is folded into a per-output
/// [`Quire`] via one `mac_raw` per chunk — PDPU-style fused
/// accumulation. Versus the pre-chunking quire panel (one 512-bit
/// quire walk per MAC) this pays the quire cost once per `kc` MACs.
/// Both the `i128` partials and the quire folds are exact, so the
/// result is bit-identical to the scalar quire reference.
fn rows_p16_deepk(a: &DecodedPlan, b: &DecodedPlan,
                  bias: Option<&BiasDec>, i0: usize, nrows: usize,
                  out: &mut [u64], tile: TileConfig,
                  kc: Option<usize>) {
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let off = P16_ACC_FRAC_OFFSET as i32;
    // Chunks must stay within the i128 headroom bound.
    let cs = kc.unwrap_or(lut::P16_CHUNK).min(lut::P16_CHUNK);
    let panel = tile.p16_panel.max(1).min(n.max(1));
    let mut quires: Vec<Quire> =
        (0..panel).map(|_| Quire::new(fmt)).collect();
    let mut acc = vec![0i128; panel];
    let mut j0 = 0usize;
    while j0 < n {
        let jw = (n - j0).min(panel);
        for r in 0..nrows {
            let i = i0 + r;
            for q in quires[..jw].iter_mut() {
                q.clear();
            }
            if let Some(bd) = bias {
                for (ni, q) in quires[..jw].iter_mut().enumerate() {
                    let s = bd.sig[j0 + ni];
                    if s != 0 {
                        q.mac_raw(s.unsigned_abs() as u128,
                                  bd.w[j0 + ni], s < 0);
                    }
                }
            }
            let mut k0 = 0usize;
            while k0 < k {
                let k1 = (k0 + cs).min(k);
                acc[..jw].fill(0);
                for kk in k0..k1 {
                    let sa = a.sig[i * k + kk];
                    if sa == 0 {
                        continue;
                    }
                    let wa = a.w[i * k + kk];
                    let bs = &b.sig[kk * n + j0..kk * n + j0 + jw];
                    let bw = &b.w[kk * n + j0..kk * n + j0 + jw];
                    for (ni, slot) in
                        acc[..jw].iter_mut().enumerate()
                    {
                        let p = sa * bs[ni];
                        if p != 0 {
                            *slot +=
                                (p as i128) << (wa + bw[ni] + off);
                        }
                    }
                }
                for (ni, q) in quires[..jw].iter_mut().enumerate() {
                    let v = acc[ni];
                    if v != 0 {
                        // The partial sum is v * 2^-56 exactly; one
                        // exact quire fold per chunk.
                        q.mac_raw(v.unsigned_abs(), -off, v < 0);
                    }
                }
                k0 = k1;
            }
            for (ni, q) in quires[..jw].iter().enumerate() {
                out[r * n + j0 + ni] = q.to_posit();
            }
        }
        j0 += jw;
    }
}

/// P16 element-at-a-time baseline (PR 1): significand product +
/// `i128` add per MAC into a heap accumulator row, full B width per
/// output row. Kept callable for `blocked_vs_unblocked_p16`.
fn rows_p16_unblocked(a: &DecodedPlan, b: &DecodedPlan,
                      bias: Option<&BiasDec>, i0: usize, nrows: usize,
                      out: &mut [u64]) {
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let off = P16_ACC_FRAC_OFFSET as i32;
    let mut acc = vec![0i128; n];
    for r in 0..nrows {
        let i = i0 + r;
        match bias {
            Some(bd) => {
                for (j, slot) in acc.iter_mut().enumerate() {
                    *slot = (bd.sig[j] as i128) << (bd.w[j] + off);
                }
            }
            None => acc.fill(0),
        }
        for kk in 0..k {
            let sa = a.sig[i * k + kk];
            if sa == 0 {
                continue;
            }
            let wa = a.w[i * k + kk];
            let bsig = &b.sig[kk * n..(kk + 1) * n];
            let bw = &b.w[kk * n..(kk + 1) * n];
            for (j, slot) in acc.iter_mut().enumerate() {
                let p = sa * bsig[j];
                if p != 0 {
                    *slot += (p as i128) << (wa + bw[j] + off);
                }
            }
        }
        for (o, &v) in out[r * n..(r + 1) * n].iter_mut().zip(&acc) {
            *o = encode_acc_i128(v, P16_ACC_FRAC_OFFSET, fmt);
        }
    }
}

/// P32 (any k) and P16 beyond the `i128` headroom: planar significand
/// products streamed into a panel of reused quires
/// ([`TileConfig::p32_panel`] columns at a time), so the B slice the
/// inner loop walks stays cache-resident across the tile's rows.
fn rows_quire_panel(a: &DecodedPlan, b: &DecodedPlan,
                    bias: Option<&BiasDec>, i0: usize, nrows: usize,
                    out: &mut [u64], tile: TileConfig) {
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let panel = tile.p32_panel.max(1).min(n.max(1));
    let mut quires: Vec<Quire> =
        (0..panel).map(|_| Quire::new(fmt)).collect();
    let mut j0 = 0usize;
    while j0 < n {
        let jw = (n - j0).min(panel);
        for r in 0..nrows {
            let i = i0 + r;
            for q in quires[..jw].iter_mut() {
                q.clear();
            }
            if let Some(bd) = bias {
                for (ni, q) in quires[..jw].iter_mut().enumerate() {
                    let s = bd.sig[j0 + ni];
                    if s != 0 {
                        q.mac_raw(s.unsigned_abs() as u128,
                                  bd.w[j0 + ni], s < 0);
                    }
                }
            }
            for kk in 0..k {
                let sa = a.sig[i * k + kk];
                if sa == 0 {
                    continue;
                }
                let wa = a.w[i * k + kk];
                let bs = &b.sig[kk * n + j0..kk * n + j0 + jw];
                let bw = &b.w[kk * n + j0..kk * n + j0 + jw];
                for (ni, q) in quires[..jw].iter_mut().enumerate() {
                    let p = sa * bs[ni];
                    if p != 0 {
                        q.mac_raw(p.unsigned_abs() as u128,
                                  wa + bw[ni], p < 0);
                    }
                }
            }
            for (ni, q) in quires[..jw].iter().enumerate() {
                out[r * n + j0 + ni] = q.to_posit();
            }
        }
        j0 += jw;
    }
}

/// Row-block height of the k-chunked quire loop: a block of rows
/// shares each streamed B k-slice, and the persistent quire grid
/// stays small (8 × panel × 64 B ≈ 16 KiB at the default panel).
const QUIRE_KCHUNK_ROWS: usize = 8;

/// P32 / generic-format streaming k-chunked loop: a
/// [`QUIRE_KCHUNK_ROWS`]-row block holds a persistent grid of quires
/// while the reduction streams past in `kc`-deep chunks — each
/// chunk's B slice (`kc` × panel planar columns) stays cache-resident
/// across the whole row block, instead of the full k-deep panel
/// being dragged through cache once per row. Quire adds are exact
/// two's-complement adds, so the reordering is bit-identical to
/// [`rows_quire_panel`].
#[allow(clippy::too_many_arguments)]
fn rows_quire_kchunk(a: &DecodedPlan, b: &DecodedPlan,
                     bias: Option<&BiasDec>, i0: usize, nrows: usize,
                     out: &mut [u64], tile: TileConfig, kc: usize) {
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let panel = tile.p32_panel.max(1).min(n.max(1));
    let rb_max = QUIRE_KCHUNK_ROWS.min(nrows.max(1));
    let mut quires: Vec<Quire> =
        (0..panel * rb_max).map(|_| Quire::new(fmt)).collect();
    let mut j0 = 0usize;
    while j0 < n {
        let jw = (n - j0).min(panel);
        let mut r0 = 0usize;
        while r0 < nrows {
            let rb = (nrows - r0).min(rb_max);
            for q in quires[..rb * jw].iter_mut() {
                q.clear();
            }
            if let Some(bd) = bias {
                for ri in 0..rb {
                    for ni in 0..jw {
                        let s = bd.sig[j0 + ni];
                        if s != 0 {
                            quires[ri * jw + ni].mac_raw(
                                s.unsigned_abs() as u128,
                                bd.w[j0 + ni], s < 0);
                        }
                    }
                }
            }
            let mut k0 = 0usize;
            while k0 < k {
                let k1 = (k0 + kc).min(k);
                for ri in 0..rb {
                    let i = i0 + r0 + ri;
                    let qrow = &mut quires[ri * jw..(ri + 1) * jw];
                    for kk in k0..k1 {
                        let sa = a.sig[i * k + kk];
                        if sa == 0 {
                            continue;
                        }
                        let wa = a.w[i * k + kk];
                        let bs =
                            &b.sig[kk * n + j0..kk * n + j0 + jw];
                        let bw = &b.w[kk * n + j0..kk * n + j0 + jw];
                        for (ni, q) in qrow.iter_mut().enumerate() {
                            let p = sa * bs[ni];
                            if p != 0 {
                                q.mac_raw(p.unsigned_abs() as u128,
                                          wa + bw[ni], p < 0);
                            }
                        }
                    }
                }
                k0 = k1;
            }
            for ri in 0..rb {
                for ni in 0..jw {
                    out[(r0 + ri) * n + j0 + ni] =
                        quires[ri * jw + ni].to_posit();
                }
            }
            r0 += rb;
        }
        j0 += jw;
    }
}

/// Quire baseline (PR 1): one full-width row of quires, all of B
/// streamed per output row. Kept callable for the bench comparisons.
fn rows_quire_unblocked(a: &DecodedPlan, b: &DecodedPlan,
                        bias: Option<&BiasDec>, i0: usize,
                        nrows: usize, out: &mut [u64]) {
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let mut quires: Vec<Quire> =
        (0..n).map(|_| Quire::new(fmt)).collect();
    for r in 0..nrows {
        let i = i0 + r;
        for q in quires.iter_mut() {
            q.clear();
        }
        if let Some(bd) = bias {
            for (j, q) in quires.iter_mut().enumerate() {
                let s = bd.sig[j];
                if s != 0 {
                    q.mac_raw(s.unsigned_abs() as u128, bd.w[j],
                              s < 0);
                }
            }
        }
        for kk in 0..k {
            let sa = a.sig[i * k + kk];
            if sa == 0 {
                continue;
            }
            let wa = a.w[i * k + kk];
            let bsig = &b.sig[kk * n..(kk + 1) * n];
            let bw = &b.w[kk * n..(kk + 1) * n];
            for (j, q) in quires.iter_mut().enumerate() {
                let p = sa * bsig[j];
                if p != 0 {
                    q.mac_raw(p.unsigned_abs() as u128, wa + bw[j],
                              p < 0);
                }
            }
        }
        for (o, q) in out[r * n..(r + 1) * n].iter_mut().zip(&quires) {
            *o = q.to_posit();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_config_spec_parsing() {
        assert_eq!(TileConfig::parse("").unwrap(),
                   TileConfig::default());
        let cfg = TileConfig::parse(
            "p16_panel=48, p32_panel=16,steal_rows=2,k_chunk=256")
            .unwrap();
        assert_eq!(cfg,
                   TileConfig { p16_panel: 48, p32_panel: 16,
                                steal_rows: 2, k_chunk: 256 });
        // Trailing comma is tolerated; whitespace is trimmed.
        let cfg = TileConfig::parse(" p32_panel = 8 ,").unwrap();
        assert_eq!(cfg.p32_panel, 8);
        assert_eq!(cfg.p16_panel, TileConfig::default().p16_panel);
        assert_eq!(cfg.k_chunk, 0);
    }

    #[test]
    fn k_chunk_threshold_semantics() {
        // Explicit chunk: engages strictly past the chunk depth.
        let t = TileConfig { k_chunk: 64, ..TileConfig::default() };
        assert_eq!(t.k_chunk_for(64), None);
        assert_eq!(t.k_chunk_for(65), Some(64));
        assert_eq!(t.k_chunk_for(1), None);
        // Automatic: engages past K_CHUNK_AUTO with the default depth.
        let d = TileConfig::default();
        assert_eq!(d.k_chunk_for(K_CHUNK_AUTO), None);
        assert_eq!(d.k_chunk_for(K_CHUNK_AUTO + 1),
                   Some(K_CHUNK_DEFAULT));
        // A huge explicit chunk disables chunking for any real k.
        let off = TileConfig { k_chunk: usize::MAX,
                               ..TileConfig::default() };
        assert_eq!(off.k_chunk_for(1 << 20), None);
    }

    #[test]
    fn tile_config_rejects_bad_specs() {
        // Unknown keys, unparsable values, missing '=': hard errors.
        assert!(TileConfig::parse("bogus=9").is_err());
        assert!(TileConfig::parse("p16_panel=oops").is_err());
        assert!(TileConfig::parse("p16_panel").is_err());
        // Overflowing counts are rejected, not wrapped or ignored.
        assert!(TileConfig::parse(
            "p32_panel=99999999999999999999999999").is_err());
        // Zero / below-minimum panels are errors, not silent clamps.
        assert!(TileConfig::parse("p16_panel=0").is_err());
        assert!(TileConfig::parse("p16_panel=3").is_err());
        assert!(TileConfig::parse("p32_panel=0").is_err());
        // steal_rows=0 / k_chunk=0 must be expressed by omission, not
        // explicitly.
        assert!(TileConfig::parse("steal_rows=0").is_err());
        assert!(TileConfig::parse("k_chunk=0").is_err());
        // Lane-minimum panels are the smallest accepted extremes.
        let cfg = TileConfig::parse(&format!(
            "p16_panel={P16_NR},p32_panel=1,steal_rows=1,k_chunk=1"))
            .unwrap();
        assert_eq!(cfg.p16_panel, P16_NR);
        assert_eq!(cfg.p32_panel, 1);
        assert_eq!(cfg.steal_rows, 1);
        assert_eq!(cfg.k_chunk, 1);
        // validate() catches builder-set (non-spec) bad values too.
        assert!(TileConfig { p16_panel: 2, ..TileConfig::default() }
            .validate()
            .is_err());
        assert!(TileConfig { p32_panel: 0, ..TileConfig::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn inner_path_tags_round_trip() {
        for p in [InnerPath::Auto, InnerPath::Portable,
                  InnerPath::Gather, InnerPath::Hybrid,
                  InnerPath::Unblocked] {
            assert_eq!(InnerPath::from_tag(p.tag()), Ok(p));
        }
        assert!(InnerPath::from_tag("fast").is_err());
        assert!(InnerPath::from_tag("Auto").is_err(),
                "tags are case-sensitive");
    }

    #[test]
    fn gather_availability_is_consistent() {
        // On non-x86 this is always false; on x86_64 it must agree
        // with the feature detection macro (smoke test: just callable).
        let _ = gather_available();
    }
}
