//! Fused-MAC GEMM front end over [`DecodedPlan`] operands.
//!
//! Per output element the kernel accumulates **exact** products in wide
//! integer fixed point and rounds **once** at the end — the same
//! contract as the quire (`Backend::PositExact` is the oracle; the
//! property tests require bit-identical words). The inner loops live in
//! [`super::simd`], organized as a tile → panel → lane hierarchy shared
//! by all three precisions:
//!
//! * **P8** — [`super::simd::P8_LANES`] exact-product LUT gathers per
//!   step into independent `i64` register lanes (offset 2^-12; headroom
//!   for k up to 2^39), with an optional AVX2 `vpgatherqq` body;
//! * **P16** — a register micro-tile of `i128` accumulators over
//!   cache-sized B panels (offset 2^-56; exact for k ≤
//!   [`super::lut::P16_CHUNK`], the quire path takes over beyond
//!   that);
//! * **P32 / long-k** — planar fields streamed into a reused panel of
//!   [`crate::posit::Quire`]s via `mac_raw` (no per-MAC decode; the
//!   512-bit register handles any depth).
//!
//! This module owns dispatch: output rows are split into chunks on a
//! [`pool::RowQueue`] and **work-stolen** by the persistent
//! [`super::pool`] workers when [`auto_threads`] judges the matrix big
//! enough — a straggler chunk (e.g. denser rows) delays only itself,
//! not a whole fixed split. Operand plans are shared read-only and
//! each claimed chunk owns a disjoint output slice, so results are
//! identical at any thread count. [`gemm_with_scope`] retains the
//! pre-pool behavior — **fixed row splits on per-call
//! `std::thread::scope` spawns** — purely as the bench baseline
//! (`steal_vs_fixed_split` in `BENCH_hotpath.json`).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::posit::{encode_from_parts, from_f64, to_f64, Parts,
                   PositFormat};

use super::autotune;
use super::isa::{self, IsaBody};
use super::plan::{self, DecodedPlan};
use super::pool::{self, RowQueue};
use super::settings::{self, KernelConfig};
use super::simd::{self, BiasDec, InnerPath, TileConfig};

/// Below this many MACs a single thread always wins (spawn cost).
const PAR_THRESHOLD: usize = 1 << 16;

/// Target MACs per thread when scaling the worker count with the
/// problem instead of jumping straight to all cores.
const PAR_GRAIN: usize = 1 << 15;

/// Pick a worker count for an `m`×`k`×`n` GEMM: 1 for small problems,
/// then one thread per [`PAR_GRAIN`] MACs up to the hardware
/// parallelism (and never more than `m`, the tiling unit). An explicit
/// [`KernelConfig::threads`] in the installed process default
/// overrides (the old `SPADE_KERNEL_THREADS` semantics, now routed
/// through [`crate::api::EngineConfig::from_env`]).
pub fn auto_threads(m: usize, k: usize, n: usize) -> usize {
    threads_for(m, k, n, &settings::current())
}

/// Worker count for one GEMM under an explicit config: the override
/// when set, else the size heuristic. The sparse front ends
/// ([`super::sparse`]) call it with the *effective* depth
/// (`nnz / rows`) so pruned matrices don't over-thread.
pub(super) fn threads_for(m: usize, k: usize, n: usize,
                          cfg: &KernelConfig) -> usize {
    if let Some(t) = cfg.threads {
        return t.clamp(1, m.max(1));
    }
    let work = m.saturating_mul(k).saturating_mul(n);
    if work < PAR_THRESHOLD {
        return 1;
    }
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    hw.min(m.max(1)).min((work / PAR_GRAIN).max(1))
}

/// Planar GEMM with automatic threading: `a` (m×k) · `b` (k×n)
/// [+ bias], one rounding per output. Returns the m×n output words.
/// Runs under the installed process-default [`KernelConfig`]
/// ([`settings::current`]).
pub fn gemm(a: &DecodedPlan, b: &DecodedPlan, bias: Option<&[u64]>)
            -> Vec<u64> {
    gemm_with_config(a, b, bias, &settings::current())
}

/// [`gemm`] under an explicit [`KernelConfig`] — the facade entry
/// point ([`crate::api::Engine`] and per-session configs route here).
/// Bit-identical to [`gemm`] for every config: threads, tiles, and
/// inner path reorder exact integer sums only.
pub fn gemm_with_config(a: &DecodedPlan, b: &DecodedPlan,
                        bias: Option<&[u64]>, cfg: &KernelConfig)
                        -> Vec<u64> {
    gemm_with_config_stats(a, b, bias, cfg).0
}

/// [`gemm_with_config`] plus the dispatch telemetry — the whole
/// config (threads, tile, inner path) governs the run, not just the
/// thread count.
pub fn gemm_with_config_stats(a: &DecodedPlan, b: &DecodedPlan,
                              bias: Option<&[u64]>,
                              cfg: &KernelConfig)
                              -> (Vec<u64>, DispatchStats) {
    let t = threads_for(a.rows, a.cols, b.cols, cfg);
    gemm_impl(a, b, bias, t, Dispatch::Pool, cfg)
}

/// [`gemm`] with an explicit worker count (1 = fully sequential).
/// The result is bit-identical at every thread count. Row chunks are
/// work-stolen off a shared [`pool::RowQueue`] by jobs on the
/// persistent [`pool`] (one job stays on the caller), so no threads
/// are spawned per call and uneven rows cannot straggle a fixed split.
pub fn gemm_with_threads(a: &DecodedPlan, b: &DecodedPlan,
                         bias: Option<&[u64]>, threads: usize)
                         -> Vec<u64> {
    gemm_impl(a, b, bias, threads, Dispatch::Pool,
              &settings::current())
        .0
}

/// [`gemm_with_threads`] plus the dispatch telemetry: how the
/// work-stealing queue carved the rows and how many chunks each job
/// claimed (the last entry is the job run inline on the caller).
/// Tests use it to assert steal-counter sanity.
pub fn gemm_with_stats(a: &DecodedPlan, b: &DecodedPlan,
                       bias: Option<&[u64]>, threads: usize)
                       -> (Vec<u64>, DispatchStats) {
    gemm_impl(a, b, bias, threads, Dispatch::Pool,
              &settings::current())
}

/// **Bench baseline — not the hot path.** [`gemm_with_threads`]
/// dispatching fixed contiguous row blocks (one per thread) through a
/// per-call `std::thread::scope`: the pre-pool, pre-work-stealing
/// behavior, kept so `benches/hotpath.rs` can measure both spawn
/// amortization (pool-vs-scope) and straggler behavior
/// (`steal_vs_fixed_split`) against the same inner loops. Speedup
/// ratios in `BENCH_hotpath.json` are relative to *this* reference.
pub fn gemm_with_scope(a: &DecodedPlan, b: &DecodedPlan,
                       bias: Option<&[u64]>, threads: usize)
                       -> Vec<u64> {
    gemm_impl(a, b, bias, threads, Dispatch::Scope,
              &settings::current())
        .0
}

/// Single-threaded GEMM with an explicitly pinned inner-loop body —
/// the bench/test entry behind `simd_vs_scalar_gather` and
/// `blocked_vs_unblocked_p16`. Returns `None` only when
/// [`InnerPath::Gather`] is requested on a machine without AVX2.
/// Every `Some` result is bit-identical to [`gemm`].
pub fn gemm_single_path(a: &DecodedPlan, b: &DecodedPlan,
                        bias: Option<&[u64]>, path: InnerPath)
                        -> Option<Vec<u64>> {
    if path == InnerPath::Gather && !simd::gather_available() {
        return None;
    }
    // The path pins predate the body axis; map them onto it the same
    // way the row dispatch does so the pinned run uses exactly the
    // body its name promises.
    let body = match path {
        InnerPath::Gather => IsaBody::Avx2,
        InnerPath::Portable => IsaBody::Portable,
        _ => isa::preferred(),
    };
    gemm_forced(a, b, bias, path, body, None)
}

/// Single-threaded GEMM with an explicitly pinned **ISA body** — the
/// forced-body bit-identity sweep's entry point
/// (`tests/isa_bodies.rs`, and the `isa_body_matrix` bench section).
/// Returns `None` when the host cannot run `body`, so callers skip
/// loudly instead of silently measuring a fallback. An explicit
/// `tile` (e.g. a small `k_chunk`) reaches the chunked variants of
/// the body; `None` uses the installed process default.
pub fn gemm_single_body(a: &DecodedPlan, b: &DecodedPlan,
                        bias: Option<&[u64]>, body: IsaBody,
                        tile: Option<TileConfig>)
                        -> Option<Vec<u64>> {
    if !isa::host_has(body) {
        return None;
    }
    gemm_forced(a, b, bias, InnerPath::Auto, body, tile)
}

/// Shared single-threaded forced-(path, body) GEMM behind the two
/// pinned entries above.
fn gemm_forced(a: &DecodedPlan, b: &DecodedPlan, bias: Option<&[u64]>,
               path: InnerPath, body: IsaBody,
               tile: Option<TileConfig>) -> Option<Vec<u64>> {
    check_shapes(a, b, bias);
    let (m, n) = (a.rows, b.cols);
    if m == 0 || n == 0 {
        return Some(Vec::new());
    }
    let bias_dec = bias.map(|bs| BiasDec::new(bs, a.fmt));
    let mut out = vec![0u64; m * n];
    let tile =
        tile.unwrap_or_else(|| settings::current().tile_or_default());
    simd::gemm_rows(a, b, bias_dec.as_ref(), 0, &mut out, path, body,
                    tile);
    apply_nar(a, b, bias_dec.as_ref(), &mut out);
    Some(out)
}

/// How the work-stealing dispatch carved one GEMM. All fields refer to
/// output rows.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DispatchStats {
    /// Rows per stealable chunk ([`simd::TileConfig::steal_rows`], or
    /// the auto heuristic).
    pub chunk_rows: usize,
    /// Total chunks the queue handed out (`ceil(m / chunk_rows)`).
    pub chunks: usize,
    /// Chunks claimed by each job; the entries sum to `chunks`. A job
    /// claiming more than `chunks / jobs` stole work from slower
    /// peers. Sequential runs report a single job with one claim.
    pub per_job_claims: Vec<usize>,
}

/// How the row-chunk jobs reach their threads.
enum Dispatch {
    /// Persistent worker pool + work-stealing row queue (the hot
    /// path).
    Pool,
    /// Fixed row splits on fresh scoped threads per call (bench
    /// baseline).
    Scope,
}

/// Shared output pointer for the work-stealing jobs.
///
/// SAFETY rationale: jobs derive disjoint `&mut [u64]` windows from
/// it, one per claimed chunk, and [`RowQueue`] hands out each chunk at
/// most once — so no two jobs ever alias a window, which is what makes
/// the `Sync` claim sound.
struct SharedOut(*mut u64);
// SAFETY: see the type-level rationale — RowQueue hands out each row
// chunk at most once, so concurrent jobs always write disjoint
// windows behind this pointer.
unsafe impl Sync for SharedOut {}

fn check_shapes(a: &DecodedPlan, b: &DecodedPlan,
                bias: Option<&[u64]>) {
    assert_eq!(a.fmt, b.fmt, "operand formats differ");
    assert_eq!(a.cols, b.rows, "inner dimensions differ");
    if let Some(bs) = bias {
        assert_eq!(bs.len(), b.cols, "bias length");
    }
}

/// Rows per stealable chunk: the [`TileConfig::steal_rows`] override
/// when set, else ~4 chunks per worker — fine enough that one
/// straggler chunk cannot hold a whole fixed share hostage, coarse
/// enough that the atomic claim is noise next to a chunk's MACs.
fn steal_chunk_rows(m: usize, threads: usize, tile: TileConfig)
                    -> usize {
    if tile.steal_rows > 0 {
        return tile.steal_rows.min(m).max(1);
    }
    (m / (threads * 4)).max(1)
}

/// Process-wide dispatch telemetry, accumulated across every GEMM
/// since process start. Cheap (three relaxed atomic adds per GEMM,
/// none per MAC); the `spade serve --stats-json` dump surfaces it so
/// fleet dashboards can watch steal pressure without instrumenting
/// the kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelCounters {
    /// GEMMs dispatched through the threaded front ends (`gemm`,
    /// `gemm_with_config`, `gemm_with_threads`, `gemm_with_scope`),
    /// at any thread count. The pinned-body bench entry
    /// ([`gemm_single_path`]) is not counted.
    pub gemms: u64,
    /// Work-stealing row chunks handed out by pool dispatch.
    pub chunks: u64,
    /// Chunks claimed by a job **beyond** its fixed-split share
    /// (`ceil(chunks / jobs)`) — the work that stealing moved off a
    /// straggler. 0 means every job kept exactly its even share.
    pub stolen_chunks: u64,
    /// Autotune micro-probes run ([`super::autotune::probes`]): one
    /// per (precision, shape class) grid timed, not per candidate.
    /// `Engine::warm_up` tests assert this stays flat once traffic
    /// starts.
    pub autotune_probes: u64,
    /// GEMMs that ran with the fused epilogue ([`gemm_fused`] /
    /// [`gemm_fused_into`]) — also counted in `gemms`.
    pub fused_gemms: u64,
    /// Output elements the fused epilogue emitted directly in planar
    /// form (each one is a `from_words` decode the next layer never
    /// pays).
    pub fused_elems: u64,
    /// GEMMs dispatched through the sparse front ends
    /// ([`super::sparse::spgemm`] family, including the `bt` and fused
    /// variants) — also counted in `gemms`. A pruned-model forward
    /// pass moving this is the proof the sparse path actually ran.
    pub sparse_gemms: u64,
    /// Elements decoded word → planar by `DecodedPlan::from_words`
    /// since process start. Flat across a fused forward pass except
    /// for cache misses and the NaR slow path.
    pub plan_decodes: u64,
    /// Elements quantized float → posit by `DecodedPlan::from_f64` /
    /// `from_f32`. On the fused path only the network input edge
    /// moves this.
    pub plan_encodes: u64,
}

static CTR_GEMMS: AtomicU64 = AtomicU64::new(0);
static CTR_CHUNKS: AtomicU64 = AtomicU64::new(0);
static CTR_STOLEN: AtomicU64 = AtomicU64::new(0);
static CTR_FUSED_GEMMS: AtomicU64 = AtomicU64::new(0);
static CTR_FUSED_ELEMS: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the process-wide [`KernelCounters`]. Monotonic.
pub fn counters() -> KernelCounters {
    KernelCounters {
        gemms: CTR_GEMMS.load(Ordering::Relaxed),
        chunks: CTR_CHUNKS.load(Ordering::Relaxed),
        stolen_chunks: CTR_STOLEN.load(Ordering::Relaxed),
        autotune_probes: autotune::probes(),
        fused_gemms: CTR_FUSED_GEMMS.load(Ordering::Relaxed),
        fused_elems: CTR_FUSED_ELEMS.load(Ordering::Relaxed),
        sparse_gemms: super::sparse::sparse_gemms(),
        plan_decodes: plan::plan_decodes(),
        plan_encodes: plan::plan_encodes(),
    }
}

/// Count one GEMM dispatched through a front end — the sparse entry
/// points ([`super::sparse`]) share the process counter with the
/// dense ones.
pub(super) fn record_gemm() {
    CTR_GEMMS.fetch_add(1, Ordering::Relaxed);
}

/// Count one fused-epilogue GEMM and the planar elements it emitted.
pub(super) fn record_fused(elems: u64) {
    CTR_FUSED_GEMMS.fetch_add(1, Ordering::Relaxed);
    CTR_FUSED_ELEMS.fetch_add(elems, Ordering::Relaxed);
}

/// Fold one pool dispatch into the process counters.
pub(super) fn record_dispatch(stats: &DispatchStats) {
    CTR_CHUNKS.fetch_add(stats.chunks as u64, Ordering::Relaxed);
    let jobs = stats.per_job_claims.len();
    if jobs > 1 {
        let fair = stats.chunks.div_ceil(jobs);
        let stolen: usize = stats
            .per_job_claims
            .iter()
            .map(|&c| c.saturating_sub(fair))
            .sum();
        if stolen > 0 {
            CTR_STOLEN.fetch_add(stolen as u64, Ordering::Relaxed);
        }
    }
}

/// Per-chunk fused-epilogue hook: called with (first row of the
/// window, the window's freshly rounded output words) immediately
/// after [`simd::gemm_rows`] fills the window — i.e. while it is
/// still cache-hot. `Sync` because pool jobs invoke it concurrently
/// on disjoint windows.
type ChunkHook<'h> = &'h (dyn Fn(usize, &mut [u64]) + Sync);

/// Row dispatch shared by the word GEMM and the fused GEMM: carve
/// `out` into row chunks, fill each through [`simd::gemm_rows`], and
/// (when a hook is given) run the fused epilogue on each chunk right
/// after it is written. Chunking never changes results — exact
/// integer accumulation is associative and the epilogue is
/// element-wise.
#[allow(clippy::too_many_arguments)]
fn run_rows(a: &DecodedPlan, b: &DecodedPlan, bd: Option<&BiasDec>,
            out: &mut [u64], threads: usize, dispatch: Dispatch,
            tile: TileConfig, path: InnerPath, body: IsaBody,
            hook: Option<ChunkHook>) -> DispatchStats {
    let (m, n) = (a.rows, b.cols);
    let t = threads.clamp(1, m);
    if t <= 1 {
        if let Some(h) = hook {
            // Sequential fused run: still process in steal-sized row
            // blocks so the epilogue touches each window while hot
            // instead of re-streaming the whole output at the end.
            let chunk_rows = steal_chunk_rows(m, 1, tile);
            let mut r0 = 0;
            while r0 < m {
                let r1 = (r0 + chunk_rows).min(m);
                let win = &mut out[r0 * n..r1 * n];
                simd::gemm_rows(a, b, bd, r0, win, path, body,
                                tile);
                h(r0, win);
                r0 = r1;
            }
            return DispatchStats {
                chunk_rows,
                chunks: m.div_ceil(chunk_rows),
                per_job_claims: vec![m.div_ceil(chunk_rows)],
            };
        }
        simd::gemm_rows(a, b, bd, 0, out, path, body, tile);
        return DispatchStats { chunk_rows: m, chunks: 1,
                               per_job_claims: vec![1] };
    }
    match dispatch {
        Dispatch::Pool => {
            let chunk_rows = steal_chunk_rows(m, t, tile);
            let queue = RowQueue::new(m, chunk_rows);
            let claims: Vec<AtomicUsize> =
                (0..t).map(|_| AtomicUsize::new(0)).collect();
            let shared = SharedOut(out.as_mut_ptr());
            {
                let (queue, claims, shared) =
                    (&queue, &claims, &shared);
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                    Vec::with_capacity(t);
                for ti in 0..t {
                    jobs.push(Box::new(move || {
                        while let Some((r0, r1)) = queue.claim() {
                            claims[ti]
                                .fetch_add(1, Ordering::Relaxed);
                            // SAFETY: the queue hands out each row
                            // range at most once (see SharedOut),
                            // so this window is exclusive; the
                            // pool scope outlives every job.
                            let chunk = unsafe {
                                std::slice::from_raw_parts_mut(
                                    shared.0.add(r0 * n),
                                    (r1 - r0) * n)
                            };
                            simd::gemm_rows(a, b, bd, r0, chunk,
                                            path, body, tile);
                            if let Some(h) = hook {
                                h(r0, chunk);
                            }
                        }
                    }));
                }
                pool::global().run_scoped(jobs);
            }
            let stats = DispatchStats {
                chunk_rows,
                chunks: queue.chunks(),
                per_job_claims: claims
                    .iter()
                    .map(|c| c.load(Ordering::Relaxed))
                    .collect(),
            };
            record_dispatch(&stats);
            stats
        }
        Dispatch::Scope => {
            debug_assert!(hook.is_none(),
                          "fused epilogue runs on pool dispatch only");
            let rows_per = m.div_ceil(t);
            let nblocks = m.div_ceil(rows_per);
            std::thread::scope(|s| {
                for (ti, chunk) in
                    out.chunks_mut(rows_per * n).enumerate()
                {
                    s.spawn(move || {
                        simd::gemm_rows(a, b, bd, ti * rows_per,
                                        chunk, path, body, tile);
                    });
                }
            });
            DispatchStats {
                chunk_rows: rows_per,
                chunks: nblocks,
                per_job_claims: vec![1; nblocks],
            }
        }
    }
}

fn gemm_impl(a: &DecodedPlan, b: &DecodedPlan, bias: Option<&[u64]>,
             threads: usize, dispatch: Dispatch, cfg: &KernelConfig)
             -> (Vec<u64>, DispatchStats) {
    check_shapes(a, b, bias);
    let (m, n) = (a.rows, b.cols);
    if m == 0 || n == 0 {
        let stats = DispatchStats { chunk_rows: 1, chunks: 0,
                                    per_job_claims: Vec::new() };
        return (Vec::new(), stats);
    }

    CTR_GEMMS.fetch_add(1, Ordering::Relaxed);
    let bias_dec = bias.map(|bs| BiasDec::new(bs, a.fmt));
    let mut out = vec![0u64; m * n];

    // Effective geometry: explicit pin > autotuned winner > defaults
    // (probing inline only under AutotuneMode::FirstUse). Any outcome
    // is bit-identical — resolution only retunes speed.
    let (tile, path, body) =
        autotune::resolve(cfg, a.fmt, m, a.cols, n);
    let stats = run_rows(a, b, bias_dec.as_ref(), &mut out, threads,
                         dispatch, tile, path, body, None);

    apply_nar(a, b, bias_dec.as_ref(), &mut out);
    (out, stats)
}

/// What the fused GEMM applies to each output element **after** the
/// kernel's single exact-accumulator rounding, while the output tile
/// is still cache-hot.
///
/// # Exactness contract
///
/// The epilogue never adds a rounding step. Per output element the
/// fused pipeline is: exact integer/quire accumulation of all `k`
/// products **plus the bias** (the bias joins the accumulator before
/// rounding, exactly as in [`gemm`]), then exactly **one** posit
/// rounding, then the word-level activation, then planar emission.
///
/// * **ReLU commutes with the rounding.** Posit rounding is monotone
///   and sign-preserving, and `round(0) = 0`, so zeroing negative
///   *words* after the rounding equals clamping a negative *exact
///   accumulator* before it — a negative exact sum rounds to a
///   negative-or-zero word either way, and both chains end at word 0.
///   NaR passes through, matching NaN through an f32 ReLU.
/// * **Planar emission is a pure change of representation** — the
///   same fields [`DecodedPlan::from_words`] would derive, emitted
///   directly so the next layer starts from planar form with zero
///   interior encode/decode round-trip.
///
/// Consequently [`gemm_fused`] output words are bit-identical to
/// [`gemm`] followed by [`activate_words`], for every activation,
/// precision, tile geometry, thread count and inner path — asserted
/// in the tests below and oracled end-to-end in
/// `tests/fused_pipeline.rs`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Epilogue {
    /// Word-level activation applied after the single rounding.
    pub act: Activation,
}

impl Epilogue {
    /// No activation — bias + rounding + planar emission only.
    pub const NONE: Epilogue = Epilogue { act: Activation::None };
    /// ReLU fused after the single rounding.
    pub const RELU: Epilogue = Epilogue { act: Activation::Relu };
    /// ReLU6 fused after the single rounding.
    pub const RELU6: Epilogue = Epilogue { act: Activation::Relu6 };

    /// The pre-`Activation` call shape: `true` → [`Epilogue::RELU`],
    /// `false` → [`Epilogue::NONE`].
    pub fn from_relu(relu: bool) -> Epilogue {
        if relu {
            Epilogue::RELU
        } else {
            Epilogue::NONE
        }
    }
}

/// An exact dyadic rational `sig · 2^exp` — the only bound values
/// [`Activation::HardTanh`] accepts, because a clamp bound must be a
/// *fixed point of posit rounding* for the clamp to commute with the
/// kernel's single rounding (the same argument that makes ReLU6's
/// `6 = 1.5·2²` exact). [`Activation::validate`] checks the bound is
/// exactly representable in the target format.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Dyadic {
    /// Signed integer significand.
    pub sig: i32,
    /// Power-of-two exponent: the value is `sig * 2^exp`.
    pub exp: i32,
}

impl Dyadic {
    /// The exact `f64` value: `sig` is inside `f64`'s exact-integer
    /// range and scaling by a power of two only shifts the exponent,
    /// so no rounding happens here (validated posit bounds keep `exp`
    /// far from `f64`'s subnormal/overflow edges).
    pub fn value(self) -> f64 {
        self.sig as f64 * 2f64.powi(self.exp)
    }
}

/// Word-level activation of the fused epilogue (and of
/// [`activate_words`], its layer-wise oracle). Every variant commutes
/// with the kernel's single rounding where stated on the variant —
/// see [`Epilogue`] for the base argument — so fusing it after the
/// rounding matches applying it to the exact accumulator before.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Activation {
    /// Identity: the rounded sum passes through untouched.
    #[default]
    None,
    /// ReLU: zero negative words (NaR passes through).
    Relu,
    /// ReLU6: zero negative words, clamp positives to 6.0 (NaR passes
    /// through). `6 = 1.5·2²` is exactly representable in every
    /// supported posit format, so `round(min(x, 6)) =
    /// min(round(x), 6)`: rounding is monotone and fixes 6, hence an
    /// exact sum above 6 rounds to a word ≥ the 6-word and clamps to
    /// it either way, and a sum ≤ 6 rounds below it and is untouched
    /// either way.
    Relu6,
    /// Leaky ReLU with a power-of-two slope `2^-shift` on the
    /// negative side (NaR passes through). The multiply is exact in
    /// `f64` (posit values are dyadic, the slope is a power of two),
    /// so the word chain performs exactly one extra posit rounding of
    /// the exact product `round(x)·2^-shift`.
    ///
    /// **Commutation is scoped, not universal**: when the rounded
    /// negative input is exact (`round(x) = x`, e.g. the
    /// maxpos/minpos/zero boundaries the tests pin), the chain equals
    /// `round(x·2^-shift)` — the ideal single-rounding result. For
    /// inexact inputs the two roundings can differ from the
    /// one-rounding ideal near saturation (an exact sum below
    /// `-maxpos·2^shift` would ideally scale back inside range, but
    /// the word chain has already clamped to `-maxpos`), which is why
    /// this variant — unlike the clamps — documents the fused and
    /// layer-wise paths as *each other's* oracle rather than the
    /// exact accumulator's: both run the identical word chain, so
    /// they stay bit-identical everywhere.
    LeakyRelu {
        /// Negative-side slope exponent: slope = `2^-shift`,
        /// `1 ..= 16` ([`Activation::validate`]).
        shift: u32,
    },
    /// Hard-tanh: clamp to `[lo, hi]` (NaR passes through). Both
    /// bounds must be exactly representable dyadics
    /// ([`Activation::validate`]), so the commutation argument is
    /// ReLU6's on both sides: rounding is monotone and fixes each
    /// bound, hence clamping rounded words equals rounding the
    /// clamped exact sum — for **every** input, not just exact ones.
    HardTanh {
        /// Lower clamp bound (≤ `hi`).
        lo: Dyadic,
        /// Upper clamp bound.
        hi: Dyadic,
    },
}

/// Sign-extend a posit word to the full `i64` two's-complement key:
/// posit words of one format compare like their values when read as
/// sign-extended integers (NaR, the most-negative key, is excluded by
/// the callers), which is what makes word-level clamps exact.
#[inline]
fn sext_key(w: u64, fmt: PositFormat) -> i64 {
    let sh = 64 - fmt.nbits;
    ((w << sh) as i64) >> sh
}

impl Activation {
    /// Check the activation's parameters make the word-level
    /// implementation exact for `fmt`: `LeakyRelu` shifts stay in
    /// `1 ..= 16` (the slope must stay a nonzero power of two well
    /// inside every format's dynamic range), and `HardTanh` bounds
    /// must be exactly representable (`round(bound) = bound` — the
    /// fixed-point property the commutation proof needs) with
    /// `lo ≤ hi`. Called at the engine's config edge; the kernel
    /// assumes validated parameters.
    pub fn validate(self, fmt: PositFormat) -> Result<(), String> {
        match self {
            Activation::None | Activation::Relu
            | Activation::Relu6 => Ok(()),
            Activation::LeakyRelu { shift } => {
                if !(1..=16).contains(&shift) {
                    return Err(format!(
                        "LeakyRelu shift {shift} out of range (1..=16)"
                    ));
                }
                Ok(())
            }
            Activation::HardTanh { lo, hi } => {
                if lo.value() > hi.value() {
                    return Err(format!(
                        "HardTanh bounds inverted: lo {} > hi {}",
                        lo.value(), hi.value()));
                }
                for (name, d) in [("lo", lo), ("hi", hi)] {
                    let v = d.value();
                    let w = from_f64(v, fmt);
                    if w == fmt.nar() || to_f64(w, fmt) != v {
                        return Err(format!(
                            "HardTanh {name} bound {v} is not exactly \
                             representable in posit({}, {})",
                            fmt.nbits, fmt.es));
                    }
                }
                Ok(())
            }
        }
    }
}

/// Word-level activation dispatch — the **single** implementation
/// both the layer-wise path and the fused epilogue
/// ([`super::simd::epilogue_window`]) run, so their bit-identity is
/// structural. No-op for identity, [`relu_words`] for ReLU, word
/// compares for the clamps (posit words of one format order like
/// their values as sign-extended integers), and one exact `f64`
/// multiply + re-round for the Leaky negative side.
pub fn activate_words(words: &mut [u64], act: Activation,
                      fmt: PositFormat) {
    match act {
        Activation::None => {}
        Activation::Relu => relu_words(words, fmt),
        Activation::Relu6 => {
            let nar = fmt.nar();
            let sign_bit = 1u64 << (fmt.nbits - 1);
            let six = from_f64(6.0, fmt);
            for wd in words.iter_mut() {
                if *wd == nar {
                    continue;
                }
                if *wd & sign_bit != 0 {
                    *wd = 0;
                } else if *wd > six {
                    *wd = six;
                }
            }
        }
        Activation::LeakyRelu { shift } => {
            let nar = fmt.nar();
            let sign_bit = 1u64 << (fmt.nbits - 1);
            // 2^-shift is exact in f64 for every validated shift, and
            // a posit value times a power of two is still dyadic, so
            // the only rounding below is `from_f64`'s — the posit
            // re-round of the exact scaled value.
            let scale = ((1u64 << shift) as f64).recip();
            for wd in words.iter_mut() {
                if *wd & sign_bit != 0 && *wd != nar {
                    *wd = from_f64(to_f64(*wd, fmt) * scale, fmt);
                }
            }
        }
        Activation::HardTanh { lo, hi } => {
            let nar = fmt.nar();
            let lo_w = from_f64(lo.value(), fmt);
            let hi_w = from_f64(hi.value(), fmt);
            let lo_k = sext_key(lo_w, fmt);
            let hi_k = sext_key(hi_w, fmt);
            for wd in words.iter_mut() {
                if *wd == nar {
                    continue;
                }
                let k = sext_key(*wd, fmt);
                if k < lo_k {
                    *wd = lo_w;
                } else if k > hi_k {
                    *wd = hi_w;
                }
            }
        }
    }
}

/// Word-level ReLU: zero every negative word, pass NaR through.
/// Bit-identical to clamping the exact accumulator before the
/// rounding (see [`Epilogue`]) and to an f32 ReLU between decode and
/// re-encode for formats whose values round-trip f32 exactly — this
/// is the layer-wise oracle the fused epilogue is tested against.
pub fn relu_words(words: &mut [u64], fmt: PositFormat) {
    let nar = fmt.nar();
    let sign_bit = 1u64 << (fmt.nbits - 1);
    for wd in words.iter_mut() {
        if *wd & sign_bit != 0 && *wd != nar {
            *wd = 0;
        }
    }
}

/// Raw planar-field sink for the fused epilogue: pool jobs write
/// disjoint `sig`/`w`/byte windows of the output plan through it.
///
/// SAFETY rationale: identical to [`SharedOut`] — each window is
/// derived from a row chunk the [`RowQueue`] hands out at most once,
/// so no two jobs ever alias.
pub(super) struct PlanarSink {
    pub(super) sig: *mut i64,
    pub(super) w: *mut i32,
    pub(super) w8: *mut u8,
}
// SAFETY: see the type-level rationale — every window handed to a job
// is derived from a RowQueue chunk claimed at most once, so the three
// planar pointers are never aliased across threads.
unsafe impl Sync for PlanarSink {}

impl PlanarSink {
    /// The planar windows for `len` elements starting at flat offset
    /// `off`.
    ///
    /// # Safety
    /// The `(off, len)` element range must be exclusive to the caller
    /// (see the type-level rationale) and in bounds of the plan the
    /// pointers were taken from.
    pub(super) unsafe fn window(&self, off: usize, len: usize)
                     -> (&mut [i64], &mut [i32], Option<&mut [u8]>) {
        let sig = std::slice::from_raw_parts_mut(self.sig.add(off),
                                                 len);
        let w = std::slice::from_raw_parts_mut(self.w.add(off), len);
        let w8 = if self.w8.is_null() {
            None
        } else {
            Some(std::slice::from_raw_parts_mut(self.w8.add(off),
                                                len))
        };
        (sig, w, w8)
    }
}

/// [`gemm_with_config`] with the fused epilogue: bias (exact
/// accumulator domain) + activation + the single rounding, emitting a
/// planar [`DecodedPlan`] directly — see [`Epilogue`] for the
/// exactness contract. Allocates a fresh plan; steady-state callers
/// use [`gemm_fused_into`] with a recycled buffer.
pub fn gemm_fused(a: &DecodedPlan, b: &DecodedPlan,
                  bias: Option<&[u64]>, epi: Epilogue,
                  cfg: &KernelConfig) -> DecodedPlan {
    let mut out = DecodedPlan::empty(a.fmt);
    gemm_fused_into(a, b, bias, epi, cfg, &mut out);
    out
}

/// [`gemm_fused`] writing into a caller-owned plan buffer whose
/// capacity is retained across calls ([`DecodedPlan::reset`]) — the
/// ping-pong half of the fused layer pipeline: layer N's output plan
/// is handed straight back as layer N+1's A-operand, and after the
/// first pass a steady-state forward allocates nothing per layer.
///
/// Dispatch (threading, autotuned tile geometry, inner path) is
/// identical to [`gemm_with_config`] — the epilogue is orthogonal to
/// tile geometry, it just rides each row chunk while it is cache-hot.
/// With any NaR operand the fused fast path is skipped: words are
/// poisoned first ([`gemm`] semantics), then activation + planar
/// emission run as a masked second pass.
pub fn gemm_fused_into(a: &DecodedPlan, b: &DecodedPlan,
                       bias: Option<&[u64]>, epi: Epilogue,
                       cfg: &KernelConfig, out: &mut DecodedPlan) {
    check_shapes(a, b, bias);
    let (m, n) = (a.rows, b.cols);
    out.reset(a.fmt, m, n);
    if m == 0 || n == 0 {
        return;
    }
    CTR_GEMMS.fetch_add(1, Ordering::Relaxed);
    CTR_FUSED_GEMMS.fetch_add(1, Ordering::Relaxed);
    CTR_FUSED_ELEMS.fetch_add((m * n) as u64, Ordering::Relaxed);
    let bias_dec = bias.map(|bs| BiasDec::new(bs, a.fmt));
    let (tile, path, body) =
        autotune::resolve(cfg, a.fmt, m, a.cols, n);
    let t = threads_for(m, a.cols, n, cfg);

    let nar_possible = a.has_nar
        || b.has_nar
        || bias_dec.as_ref().is_some_and(|bd| bd.has_nar);
    if nar_possible {
        // Slow path (rare): words first, NaR poisoning, then the
        // activation + planar pass with mask building.
        run_rows(a, b, bias_dec.as_ref(), &mut out.words, t,
                 Dispatch::Pool, tile, path, body, None);
        apply_nar(a, b, bias_dec.as_ref(), &mut out.words);
        activate_words(&mut out.words, epi.act, a.fmt);
        out.refill_planar_from_words();
        return;
    }

    // Hot path: no NaR can reach the output (rounding saturates, it
    // never overflows to NaR), so the epilogue runs per cache-hot
    // window with no masks at all.
    let fmt = a.fmt;
    let act = epi.act;
    let DecodedPlan { words, words8, sig, w, .. } = out;
    let sink = PlanarSink {
        sig: sig.as_mut_ptr(),
        w: w.as_mut_ptr(),
        w8: if words8.is_empty() {
            std::ptr::null_mut()
        } else {
            words8.as_mut_ptr()
        },
    };
    let hook = move |r0: usize, win: &mut [u64]| {
        // SAFETY: `win` is a row chunk the dispatcher owns
        // exclusively; its planar windows share that exclusivity.
        let (sig_w, w_w, w8_w) =
            unsafe { sink.window(r0 * n, win.len()) };
        simd::epilogue_window(fmt, act, win, sig_w, w_w, w8_w);
    };
    run_rows(a, b, bias_dec.as_ref(), words, t, Dispatch::Pool, tile,
             path, body, Some(&hook));
}

/// NaR poisoning pass: any NaR operand in the reduction (or bias)
/// poisons that output, exactly like the quire's absorbing NaR.
fn apply_nar(a: &DecodedPlan, b: &DecodedPlan, bias: Option<&BiasDec>,
             out: &mut [u64]) {
    let (m, n) = (a.rows, b.cols);
    let bias_nar = bias.is_some_and(|bd| bd.has_nar);
    if !(a.has_nar || b.has_nar || bias_nar) {
        return;
    }
    let nar = a.fmt.nar();
    for i in 0..m {
        let row_nar = a.has_nar && a.nar_rows[i];
        for j in 0..n {
            if row_nar
                || (b.has_nar && b.nar_cols[j])
                || (bias_nar && bias.unwrap().nar[j])
            {
                out[i * n + j] = nar;
            }
        }
    }
}

/// Round an exact `i64` fixed-point accumulator (value =
/// `acc * 2^-frac_offset`) to a posit word — the kernel's single
/// final rounding, identical to `Quire::to_posit`.
pub fn encode_acc_i64(acc: i64, frac_offset: u32, fmt: PositFormat)
                      -> u64 {
    if acc == 0 {
        return 0;
    }
    let neg = acc < 0;
    let mag = acc.unsigned_abs();
    let top = 63 - mag.leading_zeros();
    let frac = if top == 0 { 0 } else { mag & ((1u64 << top) - 1) };
    encode_from_parts(
        Parts {
            sign: neg,
            scale: top as i32 - frac_offset as i32,
            frac,
            fbits: top,
            sticky: false,
        },
        fmt,
    )
}

/// `i128` variant of [`encode_acc_i64`]: fractions beyond 63 bits are
/// compressed with a sticky bit, exactly like the quire readout.
pub fn encode_acc_i128(acc: i128, frac_offset: u32, fmt: PositFormat)
                       -> u64 {
    if acc == 0 {
        return 0;
    }
    let neg = acc < 0;
    let mag = acc.unsigned_abs();
    let top = 127 - mag.leading_zeros();
    let frac_wide = if top == 0 {
        0u128
    } else {
        mag & ((1u128 << top) - 1)
    };
    let (frac, fbits, sticky) = if top <= 63 {
        (frac_wide as u64, top, false)
    } else {
        let drop = top - 63;
        ((frac_wide >> drop) as u64, 63,
         (frac_wide & ((1u128 << drop) - 1)) != 0)
    };
    encode_from_parts(
        Parts {
            sign: neg,
            scale: top as i32 - frac_offset as i32,
            frac,
            fbits,
            sticky,
        },
        fmt,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::lut::P16_CHUNK;
    use crate::posit::{from_f64, p_mul, to_f64, Quire, P16_FMT,
                       P32_FMT, P8_FMT};
    use crate::util::SplitMix64;

    /// Scalar decode-per-MAC reference: one quire per output.
    fn quire_ref(aw: &[u64], bw: &[u64], bias: Option<&[u64]>, m: usize,
                 k: usize, n: usize, fmt: PositFormat) -> Vec<u64> {
        let mut out = vec![0u64; m * n];
        let mut q = Quire::new(fmt);
        for i in 0..m {
            for j in 0..n {
                q.clear();
                for kk in 0..k {
                    q.mac(aw[i * k + kk], bw[kk * n + j]);
                }
                if let Some(bs) = bias {
                    q.add_posit(bs[j]);
                }
                out[i * n + j] = q.to_posit();
            }
        }
        out
    }

    fn rand_words(rng: &mut SplitMix64, len: usize, fmt: PositFormat)
                  -> Vec<u64> {
        (0..len)
            .map(|_| {
                if rng.below(2) == 0 {
                    rng.next_u64() & fmt.mask() // raw patterns, NaR incl.
                } else {
                    from_f64(rng.wide(-6, 6), fmt)
                }
            })
            .collect()
    }

    #[test]
    fn matches_quire_reference_all_formats() {
        let mut rng = SplitMix64::new(2024);
        let shapes = [(1, 1, 1), (2, 3, 2), (3, 7, 5), (5, 11, 4),
                      (4, 0, 3), (1, 33, 2), (6, 2, 6), (3, 5, 17)];
        for fmt in [P8_FMT, P16_FMT, P32_FMT] {
            for (t, &(m, k, n)) in
                shapes.iter().cycle().take(24).enumerate()
            {
                let aw = rand_words(&mut rng, m * k, fmt);
                let bw = rand_words(&mut rng, k * n, fmt);
                let bias = if t % 3 == 0 {
                    None
                } else {
                    Some(rand_words(&mut rng, n, fmt))
                };
                let pa = DecodedPlan::from_words(aw.clone(), m, k, fmt);
                let pb = DecodedPlan::from_words(bw.clone(), k, n, fmt);
                let got = gemm(&pa, &pb, bias.as_deref());
                let want =
                    quire_ref(&aw, &bw, bias.as_deref(), m, k, n, fmt);
                assert_eq!(got, want,
                           "{fmt:?} shape ({m},{k},{n}) trial {t}");
            }
        }
    }

    #[test]
    fn inner_paths_are_bit_identical() {
        // Auto, Portable, Unblocked (and Gather where the CPU has it)
        // must agree word-for-word: lane/panel reordering of exact
        // integer sums cannot change the single rounding. Shapes
        // straddle the lane width so tails are exercised.
        let mut rng = SplitMix64::new(313);
        for fmt in [P8_FMT, P16_FMT, P32_FMT] {
            for &(m, k, n) in
                &[(1, 1, 1), (3, 9, 11), (5, 17, 8), (2, 40, 19)]
            {
                let aw = rand_words(&mut rng, m * k, fmt);
                let bw = rand_words(&mut rng, k * n, fmt);
                let bias = Some(rand_words(&mut rng, n, fmt));
                let pa = DecodedPlan::from_words(aw, m, k, fmt);
                let pb = DecodedPlan::from_words(bw, k, n, fmt);
                let auto = gemm_single_path(&pa, &pb, bias.as_deref(),
                                            InnerPath::Auto)
                    .unwrap();
                for path in [InnerPath::Portable, InnerPath::Hybrid,
                             InnerPath::Unblocked]
                {
                    assert_eq!(
                        gemm_single_path(&pa, &pb, bias.as_deref(),
                                         path)
                            .unwrap(),
                        auto,
                        "{fmt:?} ({m},{k},{n}) {path:?}");
                }
                if let Some(g) = gemm_single_path(
                    &pa, &pb, bias.as_deref(), InnerPath::Gather)
                {
                    assert_eq!(g, auto,
                               "{fmt:?} ({m},{k},{n}) Gather");
                }
                // and the threaded entry agrees with the pinned paths
                assert_eq!(gemm(&pa, &pb, bias.as_deref()), auto);
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut rng = SplitMix64::new(7);
        let fmt = P16_FMT;
        let (m, k, n) = (13, 9, 11); // deliberately non-divisible
        let aw = rand_words(&mut rng, m * k, fmt);
        let bw = rand_words(&mut rng, k * n, fmt);
        let pa = DecodedPlan::from_words(aw, m, k, fmt);
        let pb = DecodedPlan::from_words(bw, k, n, fmt);
        let seq = gemm_with_threads(&pa, &pb, None, 1);
        for t in [2, 3, 5, 16, 64] {
            assert_eq!(gemm_with_threads(&pa, &pb, None, t), seq,
                       "threads={t}");
        }
    }

    #[test]
    fn pool_and_scope_dispatch_agree() {
        // Same inner loops, two dispatchers: the work-stealing pool
        // must be a drop-in for the fixed-split scoped-spawn baseline
        // at every fan-out.
        let mut rng = SplitMix64::new(41);
        let fmt = P8_FMT;
        let (m, k, n) = (9, 17, 7);
        let aw = rand_words(&mut rng, m * k, fmt);
        let bw = rand_words(&mut rng, k * n, fmt);
        let pa = DecodedPlan::from_words(aw, m, k, fmt);
        let pb = DecodedPlan::from_words(bw, k, n, fmt);
        for t in [1usize, 2, 4, 9] {
            assert_eq!(gemm_with_threads(&pa, &pb, None, t),
                       gemm_with_scope(&pa, &pb, None, t), "t={t}");
        }
    }

    #[test]
    fn steal_stats_account_for_every_chunk() {
        let mut rng = SplitMix64::new(97);
        let fmt = P16_FMT;
        let (m, k, n) = (37, 19, 11); // non-divisible everything
        let aw = rand_words(&mut rng, m * k, fmt);
        let bw = rand_words(&mut rng, k * n, fmt);
        let pa = DecodedPlan::from_words(aw, m, k, fmt);
        let pb = DecodedPlan::from_words(bw, k, n, fmt);
        let (out, stats) = gemm_with_stats(&pa, &pb, None, 4);
        assert_eq!(out, gemm_with_threads(&pa, &pb, None, 1));
        assert!(stats.chunk_rows >= 1);
        assert_eq!(stats.chunks, m.div_ceil(stats.chunk_rows));
        assert_eq!(stats.per_job_claims.len(), 4);
        assert_eq!(stats.per_job_claims.iter().sum::<usize>(),
                   stats.chunks,
                   "claims must cover every chunk exactly once");
    }

    #[test]
    fn explicit_config_is_bit_identical_and_counted() {
        // An extreme-but-valid explicit KernelConfig (minimum panels,
        // one-row steal chunks, portable path, odd thread count) must
        // produce the same words as the default entry point, and the
        // process counters must see both dispatches.
        let mut rng = SplitMix64::new(2718);
        for fmt in [P8_FMT, P16_FMT, P32_FMT] {
            let (m, k, n) = (11, 13, 9);
            let aw = rand_words(&mut rng, m * k, fmt);
            let bw = rand_words(&mut rng, k * n, fmt);
            let pa = DecodedPlan::from_words(aw, m, k, fmt);
            let pb = DecodedPlan::from_words(bw, k, n, fmt);
            let before = counters();
            let base = gemm(&pa, &pb, None);
            let cfg = KernelConfig {
                threads: Some(3),
                pool_workers: None,
                tile: Some(TileConfig { p16_panel: 4, p32_panel: 1,
                                        steal_rows: 1, k_chunk: 4 }),
                path: InnerPath::Portable,
                autotune: crate::kernel::AutotuneMode::Off,
                isa: None,
            };
            assert_eq!(gemm_with_config(&pa, &pb, None, &cfg), base,
                       "{fmt:?}");
            let after = counters();
            // >= : other tests run concurrently and also count.
            assert!(after.gemms >= before.gemms + 2);
            assert!(after.chunks >= before.chunks);
            assert!(after.stolen_chunks >= before.stolen_chunks);
        }
    }

    #[test]
    fn gemms_reuse_the_persistent_pool() {
        let mut rng = SplitMix64::new(43);
        let fmt = P16_FMT;
        let (m, k, n) = (16, 8, 8);
        let aw = rand_words(&mut rng, m * k, fmt);
        let bw = rand_words(&mut rng, k * n, fmt);
        let pa = DecodedPlan::from_words(aw, m, k, fmt);
        let pb = DecodedPlan::from_words(bw, k, n, fmt);
        let pool = pool::global();
        let jobs_before = pool.jobs_executed();
        for _ in 0..8 {
            let _ = gemm_with_threads(&pa, &pb, None, 4);
        }
        // 4 stealing jobs per call: one runs inline on the caller,
        // three are queued to the shared pool — the counter proves the
        // work went through the persistent workers rather than any
        // per-call spawn path (>=: other tests may run concurrently;
        // the workers-stay-the-same-threads property is asserted by
        // pool::tests::workers_are_long_lived_across_scopes).
        assert!(pool.jobs_executed() >= jobs_before + 8 * 3,
                "pool jobs {} < {}", pool.jobs_executed(),
                jobs_before + 8 * 3);
    }

    #[test]
    fn single_mac_equals_p_mul() {
        // A 1x1x1 GEMM is just a multiply; it must round like p_mul.
        for fmt in [P8_FMT, P16_FMT, P32_FMT] {
            let mut rng = SplitMix64::new(17);
            for _ in 0..5_000 {
                let a = rng.next_u64() & fmt.mask();
                let b = rng.next_u64() & fmt.mask();
                let pa = DecodedPlan::from_words(vec![a], 1, 1, fmt);
                let pb = DecodedPlan::from_words(vec![b], 1, 1, fmt);
                let got = gemm(&pa, &pb, None)[0];
                assert_eq!(got, p_mul(a, b, fmt),
                           "{fmt:?} {a:#x}*{b:#x}");
            }
        }
    }

    #[test]
    fn p16_long_k_takes_quire_path_exactly() {
        // k beyond the i128 headroom bound must still be exact: all
        // maxpos products (the worst case for accumulator growth).
        let fmt = P16_FMT;
        let k = P16_CHUNK + 3;
        let mp = fmt.maxpos_word();
        let aw = vec![mp; k];
        let bw = vec![mp; k];
        let pa = DecodedPlan::from_words(aw.clone(), 1, k, fmt);
        let pb = DecodedPlan::from_words(bw.clone(), k, 1, fmt);
        let got = gemm(&pa, &pb, None);
        let want = quire_ref(&aw, &bw, None, 1, k, 1, fmt);
        assert_eq!(got, want);
    }

    #[test]
    fn bias_enters_before_rounding() {
        // quire semantics: bias joins the exact accumulator, so
        // sum+bias rounds once (not round(sum) + round-add(bias)).
        let fmt = P8_FMT;
        let a = from_f64(1.0, fmt);
        let pa = DecodedPlan::from_words(vec![a; 4], 1, 4, fmt);
        let pb = DecodedPlan::from_words(
            vec![from_f64(16.0, fmt); 4], 4, 1, fmt);
        let bias = vec![from_f64(0.25, fmt)];
        let got = gemm(&pa, &pb, Some(bias.as_slice()))[0];
        let want = quire_ref(&pa.words, &pb.words, Some(&bias), 1, 4, 1,
                             fmt)[0];
        assert_eq!(got, want);
        // and differs from the post-rounded chain on this instance
        assert_eq!(to_f64(got, fmt), 64.0); // 64.25 rounds to 64 once
    }

    #[test]
    fn fused_matches_word_gemm_plus_relu_all_formats() {
        // The fused epilogue must be bit-identical to the layer-wise
        // chain: word GEMM -> relu_words -> from_words. Random
        // operands include raw NaR patterns, so both the mask-free
        // hot path and the poisoned slow path are exercised.
        let mut rng = SplitMix64::new(4096);
        let cfg = settings::current();
        for fmt in [P8_FMT, P16_FMT, P32_FMT] {
            for &(m, k, n) in
                &[(1, 1, 1), (3, 9, 11), (5, 17, 8), (13, 7, 5)]
            {
                let aw = rand_words(&mut rng, m * k, fmt);
                let bw = rand_words(&mut rng, k * n, fmt);
                let bias = Some(rand_words(&mut rng, n, fmt));
                let pa = DecodedPlan::from_words(aw, m, k, fmt);
                let pb = DecodedPlan::from_words(bw, k, n, fmt);
                for relu in [false, true] {
                    let mut want_words =
                        gemm(&pa, &pb, bias.as_deref());
                    if relu {
                        relu_words(&mut want_words, fmt);
                    }
                    let want = DecodedPlan::from_words(want_words, m,
                                                       n, fmt);
                    let got = gemm_fused(&pa, &pb, bias.as_deref(),
                                         Epilogue::from_relu(relu),
                                         &cfg);
                    assert_eq!(got.words, want.words,
                               "{fmt:?} ({m},{k},{n}) relu={relu}");
                    assert_eq!(got.sig, want.sig, "{fmt:?} sig");
                    assert_eq!(got.w, want.w, "{fmt:?} w");
                    assert_eq!(got.words8, want.words8,
                               "{fmt:?} words8");
                    assert_eq!(got.has_nar, want.has_nar);
                    assert_eq!(got.nar_rows, want.nar_rows);
                    assert_eq!(got.nar_cols, want.nar_cols);
                }
            }
        }
    }

    #[test]
    fn fused_into_reuses_the_buffer_across_calls() {
        let mut rng = SplitMix64::new(515);
        let cfg = settings::current();
        let fmt = P16_FMT;
        let (m, k, n) = (9, 6, 7);
        let mut buf = DecodedPlan::empty(fmt);
        let mut ptr_after_first = std::ptr::null();
        for trial in 0..3 {
            let aw = rand_words(&mut rng, m * k, fmt);
            let bw = rand_words(&mut rng, k * n, fmt);
            let pa = DecodedPlan::from_words(aw, m, k, fmt);
            let pb = DecodedPlan::from_words(bw, k, n, fmt);
            gemm_fused_into(&pa, &pb, None, Epilogue::RELU, &cfg,
                            &mut buf);
            let fresh =
                gemm_fused(&pa, &pb, None, Epilogue::RELU, &cfg);
            assert_eq!(buf.words, fresh.words, "trial {trial}");
            assert_eq!(buf.sig, fresh.sig, "trial {trial}");
            if trial == 0 {
                ptr_after_first = buf.words.as_ptr();
            } else {
                // Same shape: the recycled buffer must not realloc.
                assert_eq!(buf.words.as_ptr(), ptr_after_first,
                           "ping-pong buffer reallocated");
            }
        }
    }

    #[test]
    fn fused_thread_counts_and_paths_agree() {
        // The epilogue is orthogonal to dispatch: explicit thread /
        // tile pins must not change the fused output.
        let mut rng = SplitMix64::new(616);
        let fmt = P8_FMT;
        let (m, k, n) = (23, 12, 9);
        let aw = rand_words(&mut rng, m * k, fmt);
        let bw = rand_words(&mut rng, k * n, fmt);
        let pa = DecodedPlan::from_words(aw, m, k, fmt);
        let pb = DecodedPlan::from_words(bw, k, n, fmt);
        let base = gemm_fused(&pa, &pb, None, Epilogue::RELU,
                              &settings::current());
        for threads in [1usize, 2, 5] {
            let cfg = KernelConfig {
                threads: Some(threads),
                pool_workers: None,
                tile: Some(TileConfig { p16_panel: 4, p32_panel: 1,
                                        steal_rows: 2, k_chunk: 4 }),
                path: InnerPath::Portable,
                autotune: crate::kernel::AutotuneMode::Off,
                isa: None,
            };
            let got = gemm_fused(&pa, &pb, None, Epilogue::RELU, &cfg);
            assert_eq!(got.words, base.words, "threads={threads}");
            assert_eq!(got.sig, base.sig);
            assert_eq!(got.words8, base.words8);
        }
    }

    #[test]
    fn fused_counts_fused_gemms_and_elems() {
        let fmt = P8_FMT;
        let pa = DecodedPlan::from_words(vec![0x40; 6], 2, 3, fmt);
        let pb = DecodedPlan::from_words(vec![0x40; 6], 3, 2, fmt);
        let before = counters();
        let _ = gemm_fused(&pa, &pb, None, Epilogue::NONE,
                           &settings::current());
        let after = counters();
        // >= : other tests run concurrently and also count.
        assert!(after.fused_gemms >= before.fused_gemms + 1);
        assert!(after.fused_elems >= before.fused_elems + 4);
        assert!(after.gemms >= before.gemms + 1);
    }

    #[test]
    fn fused_empty_shapes_reset_the_buffer() {
        let fmt = P32_FMT;
        let pa = DecodedPlan::from_words(vec![], 0, 5, fmt);
        let pb = DecodedPlan::from_words(vec![0u64; 15], 5, 3, fmt);
        let mut buf = DecodedPlan::empty(fmt);
        gemm_fused_into(&pa, &pb, None, Epilogue::RELU,
                        &settings::current(), &mut buf);
        assert!(buf.is_empty());
        assert_eq!((buf.rows, buf.cols), (0, 3));
    }

    #[test]
    fn relu_words_matches_value_relu() {
        for fmt in [P8_FMT, P16_FMT] {
            for word in 0..(1u64 << fmt.nbits) {
                let mut w = [word];
                relu_words(&mut w, fmt);
                let v = to_f64(word, fmt);
                if v.is_nan() {
                    assert_eq!(w[0], fmt.nar(), "NaR passes through");
                } else if v < 0.0 {
                    assert_eq!(w[0], 0, "{fmt:?} {word:#x}");
                } else {
                    assert_eq!(w[0], word, "{fmt:?} {word:#x}");
                }
            }
        }
    }

    #[test]
    fn relu6_words_matches_value_clamp() {
        // Exhaustive over every P8/P16 word: the word-compare clamp
        // must equal clamp-in-value-space + re-encode (both clamp
        // bounds, 0 and 6, are exactly representable so the re-encode
        // rounds nothing).
        for fmt in [P8_FMT, P16_FMT] {
            for word in 0..(1u64 << fmt.nbits) {
                let mut w = [word];
                activate_words(&mut w, Activation::Relu6, fmt);
                let v = to_f64(word, fmt);
                if v.is_nan() {
                    assert_eq!(w[0], fmt.nar(), "NaR passes through");
                } else {
                    assert_eq!(w[0], from_f64(v.clamp(0.0, 6.0), fmt),
                               "{fmt:?} {word:#x}");
                }
            }
        }
    }

    #[test]
    fn fused_matches_word_gemm_plus_activation_all_kinds() {
        // Commutation with the rounding, per activation: the fused
        // epilogue must equal word GEMM -> activate_words ->
        // from_words for identity, ReLU and ReLU6 alike. Random
        // operands include raw NaR patterns, exercising both the
        // mask-free hot path and the poisoned slow path.
        let mut rng = SplitMix64::new(8192);
        let cfg = settings::current();
        for fmt in [P8_FMT, P16_FMT, P32_FMT] {
            for &(m, k, n) in &[(1, 1, 1), (5, 9, 7), (11, 6, 13)] {
                let aw = rand_words(&mut rng, m * k, fmt);
                let bw = rand_words(&mut rng, k * n, fmt);
                let bias = Some(rand_words(&mut rng, n, fmt));
                let pa = DecodedPlan::from_words(aw, m, k, fmt);
                let pb = DecodedPlan::from_words(bw, k, n, fmt);
                for epi in
                    [Epilogue::NONE, Epilogue::RELU, Epilogue::RELU6]
                {
                    let mut want_words =
                        gemm(&pa, &pb, bias.as_deref());
                    activate_words(&mut want_words, epi.act, fmt);
                    let want = DecodedPlan::from_words(want_words, m,
                                                       n, fmt);
                    let got = gemm_fused(&pa, &pb, bias.as_deref(),
                                         epi, &cfg);
                    assert_eq!(got.words, want.words,
                               "{fmt:?} ({m},{k},{n}) {:?}", epi.act);
                    assert_eq!(got.sig, want.sig, "{fmt:?} sig");
                    assert_eq!(got.w, want.w, "{fmt:?} w");
                    assert_eq!(got.words8, want.words8);
                }
            }
        }
    }

    #[test]
    fn epilogue_from_relu_round_trips() {
        assert_eq!(Epilogue::from_relu(true), Epilogue::RELU);
        assert_eq!(Epilogue::from_relu(false), Epilogue::NONE);
        assert_eq!(Epilogue::default(), Epilogue::NONE);
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let fmt = P32_FMT;
        let pa = DecodedPlan::from_words(vec![], 0, 5, fmt);
        let pb = DecodedPlan::from_words(vec![0u64; 15], 5, 3, fmt);
        assert!(gemm(&pa, &pb, None).is_empty());
        // k = 0: outputs are just the rounded bias
        let pa = DecodedPlan::from_words(vec![], 2, 0, fmt);
        let pb = DecodedPlan::from_words(vec![], 0, 2, fmt);
        let bias = vec![from_f64(1.5, fmt), 0];
        let out = gemm(&pa, &pb, Some(bias.as_slice()));
        assert_eq!(out, vec![bias[0], 0, bias[0], 0]);
    }
}
