//! Decode-free fused-MAC GEMM over [`DecodedPlan`] operands.
//!
//! Per output element the kernel accumulates **exact** products in wide
//! integer fixed point and rounds **once** at the end — the same
//! contract as the quire (`Backend::PositExact` is the oracle; the
//! property tests require bit-identical words). Three inner loops:
//!
//! * **P8** — one `i64` add per MAC through the 256×256 exact-product
//!   LUT (offset 2^-12; headroom for k up to 2^39);
//! * **P16** — `i64` significand product + `i128` fixed-point add
//!   (offset 2^-56; exact for k ≤ [`lut::P16_CHUNK`], the quire path
//!   takes over beyond that);
//! * **P32 / long-k** — planar fields streamed into [`Quire::mac_raw`]
//!   (no per-MAC decode; the 512-bit register handles any depth).
//!
//! Row-block tiling fans the output rows across the persistent
//! [`super::pool`] workers when [`auto_threads`] judges the matrix big
//! enough; operand plans are shared read-only, each job owns a
//! disjoint output slice, so results are identical at any thread
//! count. [`gemm_with_scope`] retains the original per-call
//! `std::thread::scope` spawning as the bench baseline for spawn
//! amortization.

use crate::posit::{encode_from_parts, Parts, PositFormat, Quire,
                   P16_FMT, P8_FMT};

use super::lut::{self, P16_ACC_FRAC_OFFSET, P16_CHUNK,
                 P8_ACC_FRAC_OFFSET};
use super::plan::DecodedPlan;
use super::pool;

/// Below this many MACs a single thread always wins (spawn cost).
const PAR_THRESHOLD: usize = 1 << 16;

/// Target MACs per thread when scaling the worker count with the
/// problem instead of jumping straight to all cores.
const PAR_GRAIN: usize = 1 << 15;

/// Pick a worker count for an `m`×`k`×`n` GEMM: 1 for small problems,
/// then one thread per [`PAR_GRAIN`] MACs up to the hardware
/// parallelism (and never more than `m`, the tiling unit). The
/// `SPADE_KERNEL_THREADS` environment variable overrides.
pub fn auto_threads(m: usize, k: usize, n: usize) -> usize {
    if let Ok(s) = std::env::var("SPADE_KERNEL_THREADS") {
        if let Ok(v) = s.parse::<usize>() {
            return v.clamp(1, m.max(1));
        }
    }
    let work = m.saturating_mul(k).saturating_mul(n);
    if work < PAR_THRESHOLD {
        return 1;
    }
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    hw.min(m.max(1)).min((work / PAR_GRAIN).max(1))
}

/// Planar GEMM with automatic threading: `a` (m×k) · `b` (k×n)
/// [+ bias], one rounding per output. Returns the m×n output words.
pub fn gemm(a: &DecodedPlan, b: &DecodedPlan, bias: Option<&[u64]>)
            -> Vec<u64> {
    gemm_with_threads(a, b, bias, auto_threads(a.rows, a.cols, b.cols))
}

/// [`gemm`] with an explicit worker count (1 = fully sequential).
/// The result is bit-identical at every thread count. Row blocks run
/// on the persistent [`pool`] (one job stays on the caller), so no
/// threads are spawned per call.
pub fn gemm_with_threads(a: &DecodedPlan, b: &DecodedPlan,
                         bias: Option<&[u64]>, threads: usize)
                         -> Vec<u64> {
    gemm_impl(a, b, bias, threads, Dispatch::Pool)
}

/// [`gemm_with_threads`] dispatching through a per-call
/// `std::thread::scope` instead of the pool — the pre-pool behavior,
/// kept so `benches/hotpath.rs` can measure spawn amortization
/// (pool-vs-scope) on the same tiling.
pub fn gemm_with_scope(a: &DecodedPlan, b: &DecodedPlan,
                       bias: Option<&[u64]>, threads: usize)
                       -> Vec<u64> {
    gemm_impl(a, b, bias, threads, Dispatch::Scope)
}

/// How the row-block jobs reach their threads.
enum Dispatch {
    /// Persistent worker pool (the hot path).
    Pool,
    /// Fresh scoped threads per call (bench baseline).
    Scope,
}

fn gemm_impl(a: &DecodedPlan, b: &DecodedPlan, bias: Option<&[u64]>,
             threads: usize, dispatch: Dispatch) -> Vec<u64> {
    assert_eq!(a.fmt, b.fmt, "operand formats differ");
    assert_eq!(a.cols, b.rows, "inner dimensions differ");
    let (m, n) = (a.rows, b.cols);
    if let Some(bs) = bias {
        assert_eq!(bs.len(), n, "bias length");
    }
    if m == 0 || n == 0 {
        return Vec::new();
    }

    let bias_dec = bias.map(|bs| BiasDec::new(bs, a.fmt));
    let mut out = vec![0u64; m * n];

    let t = threads.clamp(1, m);
    if t <= 1 {
        gemm_rows(a, b, bias_dec.as_ref(), 0, &mut out);
    } else {
        let rows_per = m.div_ceil(t);
        let bd = bias_dec.as_ref();
        match dispatch {
            Dispatch::Pool => {
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                    Vec::with_capacity(t);
                for (ti, chunk) in
                    out.chunks_mut(rows_per * n).enumerate()
                {
                    jobs.push(Box::new(move || {
                        gemm_rows(a, b, bd, ti * rows_per, chunk);
                    }));
                }
                pool::global().run_scoped(jobs);
            }
            Dispatch::Scope => {
                std::thread::scope(|s| {
                    for (ti, chunk) in
                        out.chunks_mut(rows_per * n).enumerate()
                    {
                        s.spawn(move || {
                            gemm_rows(a, b, bd, ti * rows_per, chunk);
                        });
                    }
                });
            }
        }
    }

    // NaR poisoning pass: any NaR operand in the reduction (or bias)
    // poisons that output, exactly like the quire's absorbing NaR.
    let bias_nar = bias_dec.as_ref().is_some_and(|bd| bd.has_nar);
    if a.has_nar || b.has_nar || bias_nar {
        let nar = a.fmt.nar();
        for i in 0..m {
            let row_nar = a.has_nar && a.nar_rows[i];
            for j in 0..n {
                if row_nar
                    || (b.has_nar && b.nar_cols[j])
                    || (bias_nar
                        && bias_dec.as_ref().unwrap().nar[j])
                {
                    out[i * n + j] = nar;
                }
            }
        }
    }
    out
}

/// Bias row decoded once into planar fields.
struct BiasDec {
    sig: Vec<i64>,
    w: Vec<i32>,
    nar: Vec<bool>,
    has_nar: bool,
}

impl BiasDec {
    fn new(words: &[u64], fmt: PositFormat) -> BiasDec {
        let p = DecodedPlan::from_words(words.to_vec(), 1, words.len(),
                                        fmt);
        let has_nar = p.has_nar;
        // `nar` is only read when `has_nar` (it is empty otherwise).
        BiasDec { sig: p.sig, w: p.w, nar: p.nar_cols, has_nar }
    }
}

/// Compute output rows `i0 ..` into `out` (a whole-rows slice).
fn gemm_rows(a: &DecodedPlan, b: &DecodedPlan, bias: Option<&BiasDec>,
             i0: usize, out: &mut [u64]) {
    let n = b.cols;
    let nrows = out.len() / n;
    // The LUT / fixed-offset fast paths are specific to the exact
    // standard formats; anything else goes through the generic quire
    // path (correct for any posit(n, es) the crate supports).
    if a.fmt == P8_FMT {
        rows_p8(a, b, bias, i0, nrows, out);
    } else if a.fmt == P16_FMT && a.cols <= P16_CHUNK {
        rows_p16(a, b, bias, i0, nrows, out);
    } else {
        rows_quire(a, b, bias, i0, nrows, out);
    }
}

/// P8: one LUT add per MAC into an `i64` accumulator row.
fn rows_p8(a: &DecodedPlan, b: &DecodedPlan, bias: Option<&BiasDec>,
           i0: usize, nrows: usize, out: &mut [u64]) {
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let lut = lut::p8_prod_lut();
    let mut acc = vec![0i64; n];
    for r in 0..nrows {
        let i = i0 + r;
        match bias {
            Some(bd) => {
                for j in 0..n {
                    acc[j] =
                        bd.sig[j] << (bd.w[j] + P8_ACC_FRAC_OFFSET as i32);
                }
            }
            None => acc.fill(0),
        }
        let arow = &a.words[i * k..(i + 1) * k];
        for (kk, &aw) in arow.iter().enumerate() {
            if aw == 0 {
                continue;
            }
            let base = (aw as usize) << 8;
            let brow = &b.words[kk * n..(kk + 1) * n];
            for (accj, &bw) in acc.iter_mut().zip(brow) {
                *accj += lut[base | bw as usize];
            }
        }
        for (o, &v) in out[r * n..(r + 1) * n].iter_mut().zip(&acc) {
            *o = encode_acc_i64(v, P8_ACC_FRAC_OFFSET, fmt);
        }
    }
}

/// P16 (k ≤ [`P16_CHUNK`]): significand product + `i128` add per MAC.
fn rows_p16(a: &DecodedPlan, b: &DecodedPlan, bias: Option<&BiasDec>,
            i0: usize, nrows: usize, out: &mut [u64]) {
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let off = P16_ACC_FRAC_OFFSET as i32;
    let mut acc = vec![0i128; n];
    for r in 0..nrows {
        let i = i0 + r;
        match bias {
            Some(bd) => {
                for j in 0..n {
                    acc[j] = (bd.sig[j] as i128) << (bd.w[j] + off);
                }
            }
            None => acc.fill(0),
        }
        for kk in 0..k {
            let sa = a.sig[i * k + kk];
            if sa == 0 {
                continue;
            }
            let wa = a.w[i * k + kk];
            let bsig = &b.sig[kk * n..(kk + 1) * n];
            let bw = &b.w[kk * n..(kk + 1) * n];
            for j in 0..n {
                let p = sa * bsig[j];
                if p != 0 {
                    acc[j] += (p as i128) << (wa + bw[j] + off);
                }
            }
        }
        for (o, &v) in out[r * n..(r + 1) * n].iter_mut().zip(&acc) {
            *o = encode_acc_i128(v, P16_ACC_FRAC_OFFSET, fmt);
        }
    }
}

/// P32 (any k) and P16 beyond the `i128` headroom: stream planar
/// significand products into reusable quires — still decode-free.
fn rows_quire(a: &DecodedPlan, b: &DecodedPlan, bias: Option<&BiasDec>,
              i0: usize, nrows: usize, out: &mut [u64]) {
    let (k, n) = (a.cols, b.cols);
    let fmt = a.fmt;
    let mut quires: Vec<Quire> = (0..n).map(|_| Quire::new(fmt)).collect();
    for r in 0..nrows {
        let i = i0 + r;
        for q in quires.iter_mut() {
            q.clear();
        }
        if let Some(bd) = bias {
            for (j, q) in quires.iter_mut().enumerate() {
                let s = bd.sig[j];
                if s != 0 {
                    q.mac_raw(s.unsigned_abs() as u128, bd.w[j], s < 0);
                }
            }
        }
        for kk in 0..k {
            let sa = a.sig[i * k + kk];
            if sa == 0 {
                continue;
            }
            let wa = a.w[i * k + kk];
            let bsig = &b.sig[kk * n..(kk + 1) * n];
            let bw = &b.w[kk * n..(kk + 1) * n];
            for (j, q) in quires.iter_mut().enumerate() {
                let p = sa * bsig[j];
                if p != 0 {
                    q.mac_raw(p.unsigned_abs() as u128, wa + bw[j],
                              p < 0);
                }
            }
        }
        for (o, q) in out[r * n..(r + 1) * n].iter_mut().zip(&quires) {
            *o = q.to_posit();
        }
    }
}

/// Round an exact `i64` fixed-point accumulator (value =
/// `acc * 2^-frac_offset`) to a posit word — the kernel's single
/// final rounding, identical to `Quire::to_posit`.
pub fn encode_acc_i64(acc: i64, frac_offset: u32, fmt: PositFormat)
                      -> u64 {
    if acc == 0 {
        return 0;
    }
    let neg = acc < 0;
    let mag = acc.unsigned_abs();
    let top = 63 - mag.leading_zeros();
    let frac = if top == 0 { 0 } else { mag & ((1u64 << top) - 1) };
    encode_from_parts(
        Parts {
            sign: neg,
            scale: top as i32 - frac_offset as i32,
            frac,
            fbits: top,
            sticky: false,
        },
        fmt,
    )
}

/// `i128` variant of [`encode_acc_i64`]: fractions beyond 63 bits are
/// compressed with a sticky bit, exactly like the quire readout.
pub fn encode_acc_i128(acc: i128, frac_offset: u32, fmt: PositFormat)
                       -> u64 {
    if acc == 0 {
        return 0;
    }
    let neg = acc < 0;
    let mag = acc.unsigned_abs();
    let top = 127 - mag.leading_zeros();
    let frac_wide = if top == 0 {
        0u128
    } else {
        mag & ((1u128 << top) - 1)
    };
    let (frac, fbits, sticky) = if top <= 63 {
        (frac_wide as u64, top, false)
    } else {
        let drop = top - 63;
        ((frac_wide >> drop) as u64, 63,
         (frac_wide & ((1u128 << drop) - 1)) != 0)
    };
    encode_from_parts(
        Parts {
            sign: neg,
            scale: top as i32 - frac_offset as i32,
            frac,
            fbits,
            sticky,
        },
        fmt,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::{from_f64, p_mul, to_f64, P16_FMT, P32_FMT,
                       P8_FMT};
    use crate::util::SplitMix64;

    /// Scalar decode-per-MAC reference: one quire per output.
    fn quire_ref(aw: &[u64], bw: &[u64], bias: Option<&[u64]>, m: usize,
                 k: usize, n: usize, fmt: PositFormat) -> Vec<u64> {
        let mut out = vec![0u64; m * n];
        let mut q = Quire::new(fmt);
        for i in 0..m {
            for j in 0..n {
                q.clear();
                for kk in 0..k {
                    q.mac(aw[i * k + kk], bw[kk * n + j]);
                }
                if let Some(bs) = bias {
                    q.add_posit(bs[j]);
                }
                out[i * n + j] = q.to_posit();
            }
        }
        out
    }

    fn rand_words(rng: &mut SplitMix64, len: usize, fmt: PositFormat)
                  -> Vec<u64> {
        (0..len)
            .map(|_| {
                if rng.below(2) == 0 {
                    rng.next_u64() & fmt.mask() // raw patterns, NaR incl.
                } else {
                    from_f64(rng.wide(-6, 6), fmt)
                }
            })
            .collect()
    }

    #[test]
    fn matches_quire_reference_all_formats() {
        let mut rng = SplitMix64::new(2024);
        let shapes = [(1, 1, 1), (2, 3, 2), (3, 7, 5), (5, 11, 4),
                      (4, 0, 3), (1, 33, 2), (6, 2, 6)];
        for fmt in [P8_FMT, P16_FMT, P32_FMT] {
            for (t, &(m, k, n)) in
                shapes.iter().cycle().take(24).enumerate()
            {
                let aw = rand_words(&mut rng, m * k, fmt);
                let bw = rand_words(&mut rng, k * n, fmt);
                let bias = if t % 3 == 0 {
                    None
                } else {
                    Some(rand_words(&mut rng, n, fmt))
                };
                let pa = DecodedPlan::from_words(aw.clone(), m, k, fmt);
                let pb = DecodedPlan::from_words(bw.clone(), k, n, fmt);
                let got = gemm(&pa, &pb, bias.as_deref());
                let want =
                    quire_ref(&aw, &bw, bias.as_deref(), m, k, n, fmt);
                assert_eq!(got, want,
                           "{fmt:?} shape ({m},{k},{n}) trial {t}");
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut rng = SplitMix64::new(7);
        let fmt = P16_FMT;
        let (m, k, n) = (13, 9, 11); // deliberately non-divisible
        let aw = rand_words(&mut rng, m * k, fmt);
        let bw = rand_words(&mut rng, k * n, fmt);
        let pa = DecodedPlan::from_words(aw, m, k, fmt);
        let pb = DecodedPlan::from_words(bw, k, n, fmt);
        let seq = gemm_with_threads(&pa, &pb, None, 1);
        for t in [2, 3, 5, 16, 64] {
            assert_eq!(gemm_with_threads(&pa, &pb, None, t), seq,
                       "threads={t}");
        }
    }

    #[test]
    fn pool_and_scope_dispatch_agree() {
        // Same tiling, two dispatchers: the persistent pool must be a
        // drop-in for the scoped-spawn baseline at every fan-out.
        let mut rng = SplitMix64::new(41);
        let fmt = P8_FMT;
        let (m, k, n) = (9, 17, 7);
        let aw = rand_words(&mut rng, m * k, fmt);
        let bw = rand_words(&mut rng, k * n, fmt);
        let pa = DecodedPlan::from_words(aw, m, k, fmt);
        let pb = DecodedPlan::from_words(bw, k, n, fmt);
        for t in [1usize, 2, 4, 9] {
            assert_eq!(gemm_with_threads(&pa, &pb, None, t),
                       gemm_with_scope(&pa, &pb, None, t), "t={t}");
        }
    }

    #[test]
    fn gemms_reuse_the_persistent_pool() {
        let mut rng = SplitMix64::new(43);
        let fmt = P16_FMT;
        let (m, k, n) = (16, 8, 8);
        let aw = rand_words(&mut rng, m * k, fmt);
        let bw = rand_words(&mut rng, k * n, fmt);
        let pa = DecodedPlan::from_words(aw, m, k, fmt);
        let pb = DecodedPlan::from_words(bw, k, n, fmt);
        let pool = pool::global();
        let jobs_before = pool.jobs_executed();
        for _ in 0..8 {
            let _ = gemm_with_threads(&pa, &pb, None, 4);
        }
        // 4 row blocks per call: one runs inline on the caller, three
        // are queued to the shared pool — the counter proves the work
        // went through the persistent workers rather than any per-call
        // spawn path (>=: other tests may run concurrently; the
        // workers-stay-the-same-threads property is asserted by
        // pool::tests::workers_are_long_lived_across_scopes).
        assert!(pool.jobs_executed() >= jobs_before + 8 * 3,
                "pool jobs {} < {}", pool.jobs_executed(),
                jobs_before + 8 * 3);
    }

    #[test]
    fn single_mac_equals_p_mul() {
        // A 1x1x1 GEMM is just a multiply; it must round like p_mul.
        for fmt in [P8_FMT, P16_FMT, P32_FMT] {
            let mut rng = SplitMix64::new(17);
            for _ in 0..5_000 {
                let a = rng.next_u64() & fmt.mask();
                let b = rng.next_u64() & fmt.mask();
                let pa = DecodedPlan::from_words(vec![a], 1, 1, fmt);
                let pb = DecodedPlan::from_words(vec![b], 1, 1, fmt);
                let got = gemm(&pa, &pb, None)[0];
                assert_eq!(got, p_mul(a, b, fmt),
                           "{fmt:?} {a:#x}*{b:#x}");
            }
        }
    }

    #[test]
    fn p16_long_k_takes_quire_path_exactly() {
        // k beyond the i128 headroom bound must still be exact: all
        // maxpos products (the worst case for accumulator growth).
        let fmt = P16_FMT;
        let k = P16_CHUNK + 3;
        let mp = fmt.maxpos_word();
        let aw = vec![mp; k];
        let bw = vec![mp; k];
        let pa = DecodedPlan::from_words(aw.clone(), 1, k, fmt);
        let pb = DecodedPlan::from_words(bw.clone(), k, 1, fmt);
        let got = gemm(&pa, &pb, None);
        let want = quire_ref(&aw, &bw, None, 1, k, 1, fmt);
        assert_eq!(got, want);
    }

    #[test]
    fn bias_enters_before_rounding() {
        // quire semantics: bias joins the exact accumulator, so
        // sum+bias rounds once (not round(sum) + round-add(bias)).
        let fmt = P8_FMT;
        let a = from_f64(1.0, fmt);
        let pa = DecodedPlan::from_words(vec![a; 4], 1, 4, fmt);
        let pb = DecodedPlan::from_words(
            vec![from_f64(16.0, fmt); 4], 4, 1, fmt);
        let bias = vec![from_f64(0.25, fmt)];
        let got = gemm(&pa, &pb, Some(bias.as_slice()))[0];
        let want = quire_ref(&pa.words, &pb.words, Some(&bias), 1, 4, 1,
                             fmt)[0];
        assert_eq!(got, want);
        // and differs from the post-rounded chain on this instance
        assert_eq!(to_f64(got, fmt), 64.0); // 64.25 rounds to 64 once
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let fmt = P32_FMT;
        let pa = DecodedPlan::from_words(vec![], 0, 5, fmt);
        let pb = DecodedPlan::from_words(vec![0u64; 15], 5, 3, fmt);
        assert!(gemm(&pa, &pb, None).is_empty());
        // k = 0: outputs are just the rounded bias
        let pa = DecodedPlan::from_words(vec![], 2, 0, fmt);
        let pb = DecodedPlan::from_words(vec![], 0, 2, fmt);
        let bias = vec![from_f64(1.5, fmt), 0];
        let out = gemm(&pa, &pb, Some(bias.as_slice()));
        assert_eq!(out, vec![bias[0], 0, bias[0], 0]);
    }
}
