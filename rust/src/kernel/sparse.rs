//! Sparse (CSR) planar operands and the SpGEMM paths over them.
//!
//! Pruned DNN weights are mostly zeros; decoding and multiplying every
//! stored zero wastes the planar pipeline's whole budget. Following the
//! Spada SpGEMM design (ASPLOS'23: CSR storage, row-length
//! preprocessing, an adaptive per-region dataflow), a [`SparsePlan`]
//! stores **only the nonzeros** of a matrix in the same planar field
//! layout [`DecodedPlan`] uses, compressed row by row:
//!
//! ```text
//! dense 4×6                  SparsePlan (CSR)
//! ┌ 0  a  0  0  b  0 ┐       row_ptr  [0,    2,    3, 3,       6]
//! │ 0  0  c  0  0  0 │       col_idx  [1, 4, 2,    0, 3, 5]
//! │ 0  0  0  0  0  0 │       words    [a, b, c,    d, e, f]
//! └ d  0  0  e  0  f ┘       sig/w    planar fields, one per stored
//!                                     nonzero (same decode as dense)
//! ```
//!
//! Row `i`'s entries live at `row_ptr[i] .. row_ptr[i+1]`, with
//! `col_idx` **strictly ascending** inside each row — the invariant
//! every constructor validates and the bit-identity contract leans on.
//!
//! ## Bit-identity contract
//!
//! Every sparse result is **bit-identical to a dense run on the
//! densified operands**. This is structural, not approximate: the
//! dense inner loops already skip zero operands (a zero significand
//! contributes nothing to an exact integer or quire accumulator), so a
//! CSR walk over the stored nonzeros in ascending column order feeds
//! the accumulator *the same exact terms*; integer/quire addition is
//! exact and associative, so the sum — and therefore the **single**
//! rounding per output ([`gemm::encode_acc_i64`] /
//! [`gemm::encode_acc_i128`] / `Quire::to_posit`) — cannot differ.
//! `tests/sparse_gemm.rs` pins this across a
//! density × precision × epilogue sweep.
//!
//! ## Adaptive row scheduling (the Spada idea, on a real kernel)
//!
//! * **Row-length classes** ([`RowClass`], via [`classify_row`]) pick
//!   the accumulator body per row: empty rows short-circuit, P8 rows
//!   take the `i64` product-LUT lane body, P16 rows the `i128` body
//!   (or the chunk-folded quire body beyond the `i128` headroom,
//!   [`lut::P16_CHUNK`] stored terms), P32/generic rows the quire
//!   panel body.
//! * **Row-length-sorted work stealing**: rows are dispatched through
//!   the persistent [`pool`] in descending-nnz order on a
//!   [`RowQueue`], so the dense straggler rows start first and the
//!   cheap tail backfills — the schedule changes only wall-clock,
//!   never results (each output row is written by exactly one job).
//! * **Autotuned steal granularity**: the density bucket joins the
//!   autotuner's key as `ShapeClass::Sparse(density)` and its grid
//!   sweeps the steal chunk ([`super::autotune::candidates`]).
//!
//! Two operand orientations are provided:
//!
//! * [`spgemm`] — sparse A (CSR) × dense B, the classic SpGEMM.
//! * [`spgemm_bt`] — dense A × sparse **Bᵀ** (a [`SparsePlan`] holding
//!   the CSR of B's transpose, i.e. one compressed row per *output
//!   column*). This is the pruned-weight orientation
//!   [`crate::nn::exec::Session`] uses: layer weights are `[out, in]`
//!   matrices multiplied as `x · Wᵀ`, so the weight tensor's natural
//!   rows *are* the transpose's rows and
//!   [`SparsePlan::from_dense_transposed`] builds the plan without
//!   materializing a transposed dense matrix.
//!
//! Both have fused variants ([`spgemm_fused_into`] /
//! [`spgemm_bt_fused_into`]) riding the same [`Epilogue`] contract as
//! the dense kernel: bias joins the exact accumulator, one rounding,
//! word-level activation, direct planar emission.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::posit::{PositFormat, Quire, P16_FMT, P8_FMT};

use super::autotune;
use super::gemm::{self, DispatchStats, Epilogue};
use super::lut::{self, P16_ACC_FRAC_OFFSET, P8_ACC_FRAC_OFFSET};
use super::plan::DecodedPlan;
use super::pool::{self, RowQueue};
use super::settings::{self, KernelConfig};
use super::simd::{self, BiasDec, TileConfig};

/// Sparse GEMMs dispatched through the sparse front ends (also
/// counted in [`gemm::KernelCounters::gemms`]).
static CTR_SPARSE_GEMMS: AtomicU64 = AtomicU64::new(0);

/// Process-wide sparse-GEMM counter (see
/// [`gemm::KernelCounters::sparse_gemms`]).
pub(super) fn sparse_gemms() -> u64 {
    CTR_SPARSE_GEMMS.load(Ordering::Relaxed)
}

/// A posit matrix in CSR form with planar decoded fields per stored
/// nonzero — the sparse sibling of [`DecodedPlan`]. See the module
/// docs for the layout diagram and the strict-ascending `col_idx`
/// invariant.
///
/// Stored entries whose word is posit zero are permitted (they are
/// numerically inert — a zero significand contributes nothing to any
/// exact accumulator) but the [`SparsePlan::from_dense`] constructors
/// never produce them.
#[derive(Debug, Clone)]
pub struct SparsePlan {
    /// Posit format of every element.
    pub fmt: PositFormat,
    /// Logical row count of the (densified) matrix.
    pub rows: usize,
    /// Logical column count of the (densified) matrix.
    pub cols: usize,
    /// Row extents: row `i`'s entries are
    /// `row_ptr[i] .. row_ptr[i+1]`; `len == rows + 1`,
    /// `row_ptr[rows] == nnz`.
    pub row_ptr: Vec<usize>,
    /// Column index per stored entry, strictly ascending within each
    /// row.
    pub col_idx: Vec<usize>,
    /// Posit word per stored entry (canonicalized to the low `nbits`).
    pub words: Vec<u64>,
    /// Packed byte copy of `words` for 8-bit formats (empty wider) —
    /// the P8 product-LUT index, same as [`DecodedPlan::words8`].
    pub words8: Vec<u8>,
    /// Sign-folded significand per stored entry (0 for explicit zeros
    /// and NaR).
    pub sig: Vec<i64>,
    /// LSB exponent per stored entry: value = `sig * 2^w`.
    pub w: Vec<i32>,
    /// True if any stored entry is NaR.
    pub has_nar: bool,
    /// Per-row NaR mask (empty unless `has_nar`). For a transposed
    /// plan ([`SparsePlan::from_dense_transposed`]) row `j` is source
    /// **column** `j`, so this doubles as the dense `nar_cols` mask.
    pub nar_rows: Vec<bool>,
}

impl SparsePlan {
    /// Compress a dense plan to CSR, keeping every element whose word
    /// is not posit zero (NaR words are nonzero and are kept — their
    /// `sig` is 0 so they stay numerically inert, and the per-row NaR
    /// mask drives the poisoning pass). No re-decode happens: the
    /// planar fields are copied from the dense plan.
    pub fn from_dense(p: &DecodedPlan) -> SparsePlan {
        let nar = p.fmt.nar();
        let mut row_ptr = Vec::with_capacity(p.rows + 1);
        row_ptr.push(0usize);
        let mut col_idx = Vec::new();
        let mut words = Vec::new();
        let mut sig = Vec::new();
        let mut w = Vec::new();
        let mut has_nar = false;
        let mut nar_rows: Vec<bool> = Vec::new();
        for r in 0..p.rows {
            for c in 0..p.cols {
                let idx = r * p.cols + c;
                let wd = p.words[idx];
                if wd == 0 {
                    continue;
                }
                if wd == nar {
                    if !has_nar {
                        has_nar = true;
                        nar_rows = vec![false; p.rows];
                    }
                    nar_rows[r] = true;
                }
                col_idx.push(c);
                words.push(wd);
                sig.push(p.sig[idx]);
                w.push(p.w[idx]);
            }
            row_ptr.push(col_idx.len());
        }
        let words8 = if p.fmt.nbits <= 8 {
            words.iter().map(|&wd| wd as u8).collect()
        } else {
            Vec::new()
        };
        SparsePlan { fmt: p.fmt, rows: p.rows, cols: p.cols, row_ptr,
                     col_idx, words, words8, sig, w, has_nar,
                     nar_rows }
    }

    /// Compress the **transpose** of a dense plan to CSR without
    /// materializing it: the result's row `j` holds the nonzeros of
    /// `p`'s column `j` (so `rows == p.cols`, `cols == p.rows`), and
    /// `nar_rows[j]` is true exactly when `p`'s column `j` contains a
    /// NaR — matching the dense kernel's `nar_cols` poisoning. This is
    /// the weight-plan constructor for [`spgemm_bt`].
    pub fn from_dense_transposed(p: &DecodedPlan) -> SparsePlan {
        let nar = p.fmt.nar();
        let mut row_ptr = Vec::with_capacity(p.cols + 1);
        row_ptr.push(0usize);
        let mut col_idx = Vec::new();
        let mut words = Vec::new();
        let mut sig = Vec::new();
        let mut w = Vec::new();
        let mut has_nar = false;
        let mut nar_rows: Vec<bool> = Vec::new();
        for c in 0..p.cols {
            for r in 0..p.rows {
                let idx = r * p.cols + c;
                let wd = p.words[idx];
                if wd == 0 {
                    continue;
                }
                if wd == nar {
                    if !has_nar {
                        has_nar = true;
                        nar_rows = vec![false; p.cols];
                    }
                    nar_rows[c] = true;
                }
                col_idx.push(r);
                words.push(wd);
                sig.push(p.sig[idx]);
                w.push(p.w[idx]);
            }
            row_ptr.push(col_idx.len());
        }
        let words8 = if p.fmt.nbits <= 8 {
            words.iter().map(|&wd| wd as u8).collect()
        } else {
            Vec::new()
        };
        SparsePlan { fmt: p.fmt, rows: p.cols, cols: p.rows, row_ptr,
                     col_idx, words, words8, sig, w, has_nar,
                     nar_rows }
    }

    /// Build a plan from raw CSR arrays, **validating the structure**
    /// and decoding the stored words once (the same LUT/generic decode
    /// dense plans use). Hard errors, never silent fixes: a malformed
    /// `row_ptr` (wrong length, non-monotone, out of bounds), a
    /// `col_idx`/`words` length mismatch, out-of-range column indices,
    /// and duplicate or descending column indices within a row are all
    /// rejected with a message naming the offense. Explicit posit-zero
    /// words are accepted (numerically inert).
    pub fn from_csr(rows: usize, cols: usize, row_ptr: Vec<usize>,
                    col_idx: Vec<usize>, words: Vec<u64>,
                    fmt: PositFormat) -> Result<SparsePlan, String> {
        if row_ptr.len() != rows + 1 {
            return Err(format!(
                "row_ptr has {} entries for {rows} rows (want rows+1 \
                 = {})", row_ptr.len(), rows + 1));
        }
        if row_ptr[0] != 0 {
            return Err(format!("row_ptr[0] = {} (must be 0)",
                               row_ptr[0]));
        }
        for i in 0..rows {
            if row_ptr[i + 1] < row_ptr[i] {
                return Err(format!(
                    "row_ptr is not monotone at row {i}: {} > {}",
                    row_ptr[i], row_ptr[i + 1]));
            }
        }
        let nnz = row_ptr[rows];
        if col_idx.len() != nnz {
            return Err(format!(
                "col_idx has {} entries but row_ptr ends at {nnz}",
                col_idx.len()));
        }
        if words.len() != nnz {
            return Err(format!(
                "words has {} entries but row_ptr ends at {nnz}",
                words.len()));
        }
        for i in 0..rows {
            let mut prev: Option<usize> = None;
            for e in row_ptr[i]..row_ptr[i + 1] {
                let c = col_idx[e];
                if c >= cols {
                    return Err(format!(
                        "row {i}: column index {c} out of range \
                         (cols = {cols})"));
                }
                if let Some(p) = prev {
                    if c == p {
                        return Err(format!(
                            "row {i}: duplicate column index {c}"));
                    }
                    if c < p {
                        return Err(format!(
                            "row {i}: column indices not in ascending \
                             order ({p} then {c})"));
                    }
                }
                prev = Some(c);
            }
        }
        // Decode the stored words exactly as a dense plan would (one
        // LUT/generic pass); the 1×nnz plan's nar_cols is a per-entry
        // NaR flag we fold into the per-row mask.
        let dec = DecodedPlan::from_words(words, 1, nnz, fmt);
        let mut has_nar = false;
        let mut nar_rows: Vec<bool> = Vec::new();
        if dec.has_nar {
            has_nar = true;
            nar_rows = vec![false; rows];
            for i in 0..rows {
                for e in row_ptr[i]..row_ptr[i + 1] {
                    if dec.nar_cols[e] {
                        nar_rows[i] = true;
                    }
                }
            }
        }
        Ok(SparsePlan { fmt, rows, cols, row_ptr, col_idx,
                        words: dec.words, words8: dec.words8,
                        sig: dec.sig, w: dec.w, has_nar, nar_rows })
    }

    /// Expand back to a dense [`DecodedPlan`] (zeros everywhere no
    /// entry is stored) — the densified operand the bit-identity
    /// tests run the dense oracle on.
    pub fn densify(&self) -> DecodedPlan {
        let mut words = vec![0u64; self.rows * self.cols];
        for i in 0..self.rows {
            for e in self.row_ptr[i]..self.row_ptr[i + 1] {
                words[i * self.cols + self.col_idx[e]] = self.words[e];
            }
        }
        DecodedPlan::from_words(words, self.rows, self.cols, self.fmt)
    }

    /// Stored entry count.
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Stored-entry fraction: `nnz / (rows * cols)` (0.0 for an empty
    /// shape).
    pub fn density(&self) -> f64 {
        let total = self.rows * self.cols;
        if total == 0 {
            0.0
        } else {
            self.nnz() as f64 / total as f64
        }
    }

    /// Stored entries in row `r`.
    pub fn row_nnz(&self, r: usize) -> usize {
        self.row_ptr[r + 1] - self.row_ptr[r]
    }

    /// Entry range of row `r` (indexes `col_idx`/`words`/`sig`/`w`).
    pub fn row_entries(&self, r: usize) -> std::ops::Range<usize> {
        self.row_ptr[r]..self.row_ptr[r + 1]
    }
}

/// Per-row accumulator class — the adaptive-dataflow decision
/// ([`classify_row`]): which exact accumulator body a compressed row
/// of `nnz` stored terms runs. The choice never affects results (all
/// bodies are exact); it only picks the cheapest machinery with
/// headroom for the row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowClass {
    /// No stored entries: the output row is the rounded bias (or
    /// zeros).
    Empty,
    /// The format's direct wide-integer body: P8 `i64` product-LUT
    /// lanes, P16 `i128` (exact up to [`lut::P16_CHUNK`] terms), or
    /// the quire panel for P32/generic formats.
    Direct,
    /// P16 with more stored terms than the `i128` headroom admits:
    /// exact `i128` partials over [`lut::P16_CHUNK`]-term chunks,
    /// each folded into a per-column quire with one `mac_raw`.
    DeepFold,
}

/// Classify one compressed row by stored-term count (see
/// [`RowClass`]).
pub fn classify_row(fmt: PositFormat, nnz: usize) -> RowClass {
    if nnz == 0 {
        RowClass::Empty
    } else if fmt == P16_FMT && nnz > lut::P16_CHUNK {
        RowClass::DeepFold
    } else {
        RowClass::Direct
    }
}

/// Per-job scratch buffers, allocated once per stealing job and
/// reused across every row it claims (the sparse analogue of the
/// dense loops' per-call accumulator buffers).
struct Scratch {
    acc64: Vec<i64>,
    acc128: Vec<i128>,
    quires: Vec<Quire>,
}

impl Scratch {
    fn new() -> Scratch {
        Scratch { acc64: Vec::new(), acc128: Vec::new(),
                  quires: Vec::new() }
    }

    /// At least `len` reusable quires of `fmt`.
    fn quires(&mut self, fmt: PositFormat, len: usize) -> &mut [Quire] {
        while self.quires.len() < len {
            self.quires.push(Quire::new(fmt));
        }
        &mut self.quires[..len]
    }
}

/// Shared output pointer for the work-stealing jobs — same rationale
/// as the dense dispatcher's: each claimed position maps to one row
/// of a permutation, so no two jobs ever alias a row window.
struct SharedOut(*mut u64);
// SAFETY: see the rationale above — each claimed position maps to one
// row of the nnz-sorted permutation, so concurrent jobs write disjoint
// row windows behind this pointer.
unsafe impl Sync for SharedOut {}

// ---------------------------------------------------------------
// Sparse-A row bodies (one output row per call, full column width)
// ---------------------------------------------------------------

/// P8 sparse row: `i64` accumulators over the full output row, one
/// exact-product LUT gather per (stored A entry × B column) — the
/// same terms the dense lane loop adds (it skips `aw == 0`), in the
/// same ascending-k order.
fn sprow_p8(a: &SparsePlan, b: &DecodedPlan, bd: Option<&BiasDec>,
            i: usize, orow: &mut [u64], s: &mut Scratch) {
    let n = b.cols;
    let fmt = a.fmt;
    let table = lut::p8_prod_lut();
    s.acc64.clear();
    s.acc64.resize(n, 0);
    if let Some(bd) = bd {
        for (j, slot) in s.acc64.iter_mut().enumerate() {
            *slot = bd.sig[j] << (bd.w[j] + P8_ACC_FRAC_OFFSET as i32);
        }
    }
    for e in a.row_entries(i) {
        let aw = a.words8[e];
        if aw == 0 {
            continue; // explicit stored zero: inert
        }
        let base = (aw as usize) << 8;
        let kk = a.col_idx[e];
        let brow = &b.words8[kk * n..(kk + 1) * n];
        for (slot, &bw) in s.acc64.iter_mut().zip(brow) {
            *slot += table[base | bw as usize];
        }
    }
    for (o, &v) in orow.iter_mut().zip(&s.acc64) {
        *o = gemm::encode_acc_i64(v, P8_ACC_FRAC_OFFSET, fmt);
    }
}

/// P16 sparse row within the `i128` headroom (`nnz ≤
/// [`lut::P16_CHUNK`]`): significand product + shift-add per stored
/// term, exactly the dense micro-tile's arithmetic.
fn sprow_p16(a: &SparsePlan, b: &DecodedPlan, bd: Option<&BiasDec>,
             i: usize, orow: &mut [u64], s: &mut Scratch) {
    let n = b.cols;
    let fmt = a.fmt;
    let off = P16_ACC_FRAC_OFFSET as i32;
    s.acc128.clear();
    s.acc128.resize(n, 0);
    if let Some(bd) = bd {
        for (j, slot) in s.acc128.iter_mut().enumerate() {
            *slot = (bd.sig[j] as i128) << (bd.w[j] + off);
        }
    }
    for e in a.row_entries(i) {
        let sa = a.sig[e];
        if sa == 0 {
            continue; // explicit zero or NaR entry: inert
        }
        let wa = a.w[e];
        let kk = a.col_idx[e];
        let bs = &b.sig[kk * n..(kk + 1) * n];
        let bw = &b.w[kk * n..(kk + 1) * n];
        for (j, slot) in s.acc128.iter_mut().enumerate() {
            let p = sa * bs[j];
            if p != 0 {
                *slot += (p as i128) << (wa + bw[j] + off);
            }
        }
    }
    for (o, &v) in orow.iter_mut().zip(&s.acc128) {
        *o = gemm::encode_acc_i128(v, P16_ACC_FRAC_OFFSET, fmt);
    }
}

/// P16 deep row (`nnz > [`lut::P16_CHUNK`]`): exact `i128` partials
/// over chunks of stored terms, folded into per-column quires with
/// one `mac_raw` per chunk — the sparse mirror of the dense deep-k
/// loop. Column panels bound the live quire count.
fn sprow_p16_deep(a: &SparsePlan, b: &DecodedPlan,
                  bd: Option<&BiasDec>, i: usize, orow: &mut [u64],
                  tile: TileConfig, s: &mut Scratch) {
    let n = b.cols;
    let off = P16_ACC_FRAC_OFFSET as i32;
    let cs = lut::P16_CHUNK;
    let panel = tile.p16_panel.max(1).min(n.max(1));
    let (e0, e1) = (a.row_ptr[i], a.row_ptr[i + 1]);
    s.acc128.clear();
    s.acc128.resize(panel, 0);
    let mut j0 = 0usize;
    while j0 < n {
        let jw = (n - j0).min(panel);
        let qs = {
            while s.quires.len() < jw {
                s.quires.push(Quire::new(a.fmt));
            }
            &mut s.quires[..jw]
        };
        for q in qs.iter_mut() {
            q.clear();
        }
        if let Some(bd) = bd {
            for (ni, q) in qs.iter_mut().enumerate() {
                let sb = bd.sig[j0 + ni];
                if sb != 0 {
                    q.mac_raw(sb.unsigned_abs() as u128, bd.w[j0 + ni],
                              sb < 0);
                }
            }
        }
        let mut c0 = e0;
        while c0 < e1 {
            let c1 = (c0 + cs).min(e1);
            s.acc128[..jw].fill(0);
            for e in c0..c1 {
                let sa = a.sig[e];
                if sa == 0 {
                    continue;
                }
                let wa = a.w[e];
                let kk = a.col_idx[e];
                let bs = &b.sig[kk * n + j0..kk * n + j0 + jw];
                let bw = &b.w[kk * n + j0..kk * n + j0 + jw];
                for (ni, slot) in s.acc128[..jw].iter_mut().enumerate()
                {
                    let p = sa * bs[ni];
                    if p != 0 {
                        *slot += (p as i128) << (wa + bw[ni] + off);
                    }
                }
            }
            for (ni, q) in qs.iter_mut().enumerate() {
                let v = s.acc128[ni];
                if v != 0 {
                    q.mac_raw(v.unsigned_abs(), -off, v < 0);
                }
            }
            c0 = c1;
        }
        for (ni, q) in qs.iter().enumerate() {
            orow[j0 + ni] = q.to_posit();
        }
        j0 += jw;
    }
}

/// P32 / generic-format sparse row: per-column quires walked panel by
/// panel ([`TileConfig::p32_panel`] bounds the live quire count),
/// `mac_raw` per stored term — the quire is exact at any depth.
fn sprow_quire(a: &SparsePlan, b: &DecodedPlan, bd: Option<&BiasDec>,
               i: usize, orow: &mut [u64], tile: TileConfig,
               s: &mut Scratch) {
    let n = b.cols;
    let panel = tile.p32_panel.max(1).min(n.max(1));
    let (e0, e1) = (a.row_ptr[i], a.row_ptr[i + 1]);
    let mut j0 = 0usize;
    while j0 < n {
        let jw = (n - j0).min(panel);
        let qs = s.quires(a.fmt, jw);
        for q in qs.iter_mut() {
            q.clear();
        }
        if let Some(bd) = bd {
            for (ni, q) in qs.iter_mut().enumerate() {
                let sb = bd.sig[j0 + ni];
                if sb != 0 {
                    q.mac_raw(sb.unsigned_abs() as u128, bd.w[j0 + ni],
                              sb < 0);
                }
            }
        }
        for e in e0..e1 {
            let sa = a.sig[e];
            if sa == 0 {
                continue;
            }
            let wa = a.w[e];
            let kk = a.col_idx[e];
            let bs = &b.sig[kk * n + j0..kk * n + j0 + jw];
            let bw = &b.w[kk * n + j0..kk * n + j0 + jw];
            for (ni, q) in qs.iter_mut().enumerate() {
                let p = sa * bs[ni];
                if p != 0 {
                    q.mac_raw(p.unsigned_abs() as u128, wa + bw[ni],
                              p < 0);
                }
            }
        }
        for (ni, q) in qs.iter().enumerate() {
            orow[j0 + ni] = q.to_posit();
        }
        j0 += jw;
    }
}

/// One sparse-A output row, dispatched to the [`RowClass`]-matched
/// body for its format and stored-term count.
fn sparse_row(a: &SparsePlan, b: &DecodedPlan, bd: Option<&BiasDec>,
              i: usize, orow: &mut [u64], tile: TileConfig,
              s: &mut Scratch) {
    if a.row_nnz(i) == 0 && bd.is_none() {
        orow.fill(0); // RowClass::Empty, no bias: all-zero row
        return;
    }
    if a.fmt == P8_FMT {
        sprow_p8(a, b, bd, i, orow, s);
    } else if a.fmt == P16_FMT {
        match classify_row(a.fmt, a.row_nnz(i)) {
            RowClass::DeepFold => {
                sprow_p16_deep(a, b, bd, i, orow, tile, s)
            }
            _ => sprow_p16(a, b, bd, i, orow, s),
        }
    } else {
        sprow_quire(a, b, bd, i, orow, tile, s);
    }
}

// ---------------------------------------------------------------
// Dense-A × sparse-Bᵀ row bodies (the pruned-weight orientation)
// ---------------------------------------------------------------

/// One dense-A output row against a CSR Bᵀ: output column `j` walks
/// `bt`'s compressed row `j` (its `col_idx` are k-indices, ascending
/// — the dense loop's k order), one private exact accumulator per
/// output element.
fn bt_row(a: &DecodedPlan, bt: &SparsePlan, bd: Option<&BiasDec>,
          i: usize, orow: &mut [u64], s: &mut Scratch) {
    let k = a.cols;
    let n = bt.rows;
    let fmt = a.fmt;
    if fmt == P8_FMT {
        let table = lut::p8_prod_lut();
        for j in 0..n {
            let mut acc = match bd {
                Some(bd) => {
                    bd.sig[j] << (bd.w[j] + P8_ACC_FRAC_OFFSET as i32)
                }
                None => 0,
            };
            for e in bt.row_entries(j) {
                let aw = a.words8[i * k + bt.col_idx[e]];
                if aw == 0 {
                    continue;
                }
                acc += table[((aw as usize) << 8)
                    | bt.words8[e] as usize];
            }
            orow[j] = gemm::encode_acc_i64(acc, P8_ACC_FRAC_OFFSET,
                                           fmt);
        }
    } else if fmt == P16_FMT {
        let off = P16_ACC_FRAC_OFFSET as i32;
        for j in 0..n {
            if bt.row_nnz(j) > lut::P16_CHUNK {
                // Deep column: chunk-fold into a single quire.
                let q = &mut s.quires(fmt, 1)[0];
                q.clear();
                if let Some(bd) = bd {
                    let sb = bd.sig[j];
                    if sb != 0 {
                        q.mac_raw(sb.unsigned_abs() as u128, bd.w[j],
                                  sb < 0);
                    }
                }
                let (e0, e1) = (bt.row_ptr[j], bt.row_ptr[j + 1]);
                let mut c0 = e0;
                while c0 < e1 {
                    let c1 = (c0 + lut::P16_CHUNK).min(e1);
                    let mut acc: i128 = 0;
                    for e in c0..c1 {
                        let sb = bt.sig[e];
                        if sb == 0 {
                            continue;
                        }
                        let idx = i * k + bt.col_idx[e];
                        let sa = a.sig[idx];
                        let p = sa * sb;
                        if p != 0 {
                            acc += (p as i128)
                                << (a.w[idx] + bt.w[e] + off);
                        }
                    }
                    if acc != 0 {
                        q.mac_raw(acc.unsigned_abs(), -off, acc < 0);
                    }
                    c0 = c1;
                }
                orow[j] = q.to_posit();
            } else {
                let mut acc = match bd {
                    Some(bd) => (bd.sig[j] as i128) << (bd.w[j] + off),
                    None => 0i128,
                };
                for e in bt.row_entries(j) {
                    let sb = bt.sig[e];
                    if sb == 0 {
                        continue;
                    }
                    let idx = i * k + bt.col_idx[e];
                    let sa = a.sig[idx];
                    let p = sa * sb;
                    if p != 0 {
                        acc +=
                            (p as i128) << (a.w[idx] + bt.w[e] + off);
                    }
                }
                orow[j] = gemm::encode_acc_i128(
                    acc, P16_ACC_FRAC_OFFSET, fmt);
            }
        }
    } else {
        for j in 0..n {
            let q = &mut s.quires(fmt, 1)[0];
            q.clear();
            if let Some(bd) = bd {
                let sb = bd.sig[j];
                if sb != 0 {
                    q.mac_raw(sb.unsigned_abs() as u128, bd.w[j],
                              sb < 0);
                }
            }
            for e in bt.row_entries(j) {
                let sb = bt.sig[e];
                if sb == 0 {
                    continue;
                }
                let idx = i * k + bt.col_idx[e];
                let sa = a.sig[idx];
                let p = sa * sb;
                if p != 0 {
                    q.mac_raw(p.unsigned_abs() as u128,
                              a.w[idx] + bt.w[e], p < 0);
                }
            }
            orow[j] = q.to_posit();
        }
    }
}

// ---------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------

/// Descending-nnz row permutation — the Spada-style row-length-sorted
/// schedule: the expensive rows are claimed first, the cheap tail
/// backfills the stragglers. Stable sort → deterministic order.
fn nnz_order(a: &SparsePlan) -> Vec<usize> {
    let mut order: Vec<usize> = (0..a.rows).collect();
    order.sort_by_key(|&r| std::cmp::Reverse(a.row_nnz(r)));
    order
}

/// Row dispatch shared by every sparse front end: positions on a
/// work-stealing [`RowQueue`] map through an optional permutation to
/// output rows; each claimed row is computed by `row_fn` into its
/// exclusive window and (for the fused paths) finished by `hook`
/// while cache-hot. Scheduling changes wall-clock only — each row is
/// written by exactly one job, and every accumulator is exact.
#[allow(clippy::too_many_arguments)]
fn run_sparse_rows(
    m: usize, n: usize, out: &mut [u64], threads: usize,
    tile: TileConfig, order: Option<&[usize]>,
    row_fn: &(dyn Fn(usize, &mut [u64], &mut Scratch) + Sync),
    hook: Option<&(dyn Fn(usize, &mut [u64]) + Sync)>,
) -> DispatchStats {
    let t = threads.clamp(1, m.max(1));
    if t <= 1 {
        let mut s = Scratch::new();
        for p in 0..m {
            let r = order.map_or(p, |o| o[p]);
            let win = &mut out[r * n..(r + 1) * n];
            row_fn(r, win, &mut s);
            if let Some(h) = hook {
                h(r, win);
            }
        }
        return DispatchStats { chunk_rows: m.max(1), chunks: 1,
                               per_job_claims: vec![1] };
    }
    let chunk_rows = if tile.steal_rows > 0 {
        tile.steal_rows.min(m).max(1)
    } else {
        (m / (t * 4)).max(1)
    };
    let queue = RowQueue::new(m, chunk_rows);
    let claims: Vec<AtomicUsize> =
        (0..t).map(|_| AtomicUsize::new(0)).collect();
    let shared = SharedOut(out.as_mut_ptr());
    {
        let (queue, claims, shared) = (&queue, &claims, &shared);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(t);
        for ti in 0..t {
            jobs.push(Box::new(move || {
                let mut s = Scratch::new();
                while let Some((p0, p1)) = queue.claim() {
                    claims[ti].fetch_add(1, Ordering::Relaxed);
                    for p in p0..p1 {
                        let r = order.map_or(p, |o| o[p]);
                        // SAFETY: the queue hands out each position
                        // at most once and `order` is a permutation,
                        // so row r's window is exclusive to this
                        // claim; the pool scope outlives every job.
                        let win = unsafe {
                            std::slice::from_raw_parts_mut(
                                shared.0.add(r * n), n)
                        };
                        row_fn(r, win, &mut s);
                        if let Some(h) = hook {
                            h(r, win);
                        }
                    }
                }
            }));
        }
        pool::global().run_scoped(jobs);
    }
    let stats = DispatchStats {
        chunk_rows,
        chunks: queue.chunks(),
        per_job_claims: claims
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect(),
    };
    gemm::record_dispatch(&stats);
    stats
}

// ---------------------------------------------------------------
// NaR poisoning
// ---------------------------------------------------------------

/// NaR poisoning for sparse-A × dense-B: a NaR anywhere in A's row
/// `i`, B's column `j`, or the bias poisons output (i, j) — the
/// quire's absorbing NaR, identical to the dense pass on the
/// densified operands.
fn apply_nar_a(a: &SparsePlan, b: &DecodedPlan, bd: Option<&BiasDec>,
               out: &mut [u64]) {
    let bias_nar = bd.is_some_and(|d| d.has_nar);
    if !(a.has_nar || b.has_nar || bias_nar) {
        return;
    }
    let (m, n) = (a.rows, b.cols);
    let nar = a.fmt.nar();
    for i in 0..m {
        let row_nar = a.has_nar && a.nar_rows[i];
        for j in 0..n {
            if row_nar
                || (b.has_nar && b.nar_cols[j])
                || (bias_nar && bd.unwrap().nar[j])
            {
                out[i * n + j] = nar;
            }
        }
    }
}

/// NaR poisoning for dense-A × sparse-Bᵀ: `bt.nar_rows[j]` is true
/// exactly when B's column `j` holds a NaR (see
/// [`SparsePlan::from_dense_transposed`]), so this is the dense
/// `nar_cols` pass verbatim.
fn apply_nar_bt(a: &DecodedPlan, bt: &SparsePlan,
                bd: Option<&BiasDec>, out: &mut [u64]) {
    let bias_nar = bd.is_some_and(|d| d.has_nar);
    if !(a.has_nar || bt.has_nar || bias_nar) {
        return;
    }
    let (m, n) = (a.rows, bt.rows);
    let nar = a.fmt.nar();
    for i in 0..m {
        let row_nar = a.has_nar && a.nar_rows[i];
        for j in 0..n {
            if row_nar
                || (bt.has_nar && bt.nar_rows[j])
                || (bias_nar && bd.unwrap().nar[j])
            {
                out[i * n + j] = nar;
            }
        }
    }
}

// ---------------------------------------------------------------
// Front ends
// ---------------------------------------------------------------

fn check_shapes_a(a: &SparsePlan, b: &DecodedPlan,
                  bias: Option<&[u64]>) {
    assert_eq!(a.fmt, b.fmt, "operand formats differ");
    assert_eq!(a.cols, b.rows, "inner dimensions differ");
    if let Some(bs) = bias {
        assert_eq!(bs.len(), b.cols, "bias length");
    }
}

fn check_shapes_bt(a: &DecodedPlan, bt: &SparsePlan,
                   bias: Option<&[u64]>) {
    assert_eq!(a.fmt, bt.fmt, "operand formats differ");
    assert_eq!(a.cols, bt.cols,
               "inner dimensions differ (bt holds the CSR of B's \
                transpose: bt.cols must equal k)");
    if let Some(bs) = bias {
        assert_eq!(bs.len(), bt.rows, "bias length");
    }
}

/// Sparse-A (CSR) × dense-B GEMM [+ bias] under the installed
/// process-default [`KernelConfig`]: one rounding per output,
/// **bit-identical** to [`gemm::gemm`] on [`SparsePlan::densify`]'d
/// A (the module-level contract). Returns the m×n output words.
pub fn spgemm(a: &SparsePlan, b: &DecodedPlan, bias: Option<&[u64]>)
              -> Vec<u64> {
    spgemm_with_config(a, b, bias, &settings::current())
}

/// [`spgemm`] under an explicit [`KernelConfig`] — threads, tile
/// geometry and density-bucketed autotuning
/// ([`super::autotune::classify_sparse`]) resolve exactly like the
/// dense front end; every outcome is bit-identical.
pub fn spgemm_with_config(a: &SparsePlan, b: &DecodedPlan,
                          bias: Option<&[u64]>, cfg: &KernelConfig)
                          -> Vec<u64> {
    check_shapes_a(a, b, bias);
    let (m, n) = (a.rows, b.cols);
    if m == 0 || n == 0 {
        return Vec::new();
    }
    gemm::record_gemm();
    CTR_SPARSE_GEMMS.fetch_add(1, Ordering::Relaxed);
    let bd = bias.map(|bs| BiasDec::new(bs, a.fmt));
    let (tile, _path, _body) =
        autotune::resolve_sparse(cfg, a.fmt, a.rows, a.cols, a.nnz());
    let eff_k = (a.nnz() / m).max(1);
    let t = gemm::threads_for(m, eff_k, n, cfg);
    let mut out = vec![0u64; m * n];
    let order = nnz_order(a);
    let bd_ref = bd.as_ref();
    run_sparse_rows(m, n, &mut out, t, tile, Some(&order),
                    &|r, win, s| sparse_row(a, b, bd_ref, r, win,
                                            tile, s),
                    None);
    apply_nar_a(a, b, bd_ref, &mut out);
    out
}

/// [`spgemm`] with the fused epilogue, allocating a fresh plan —
/// steady-state callers use [`spgemm_fused_into`].
pub fn spgemm_fused(a: &SparsePlan, b: &DecodedPlan,
                    bias: Option<&[u64]>, epi: Epilogue,
                    cfg: &KernelConfig) -> DecodedPlan {
    let mut out = DecodedPlan::empty(a.fmt);
    spgemm_fused_into(a, b, bias, epi, cfg, &mut out);
    out
}

/// Fused sparse-A GEMM into a recycled plan buffer: bias in the exact
/// accumulator, one rounding, word-level activation, direct planar
/// emission — the [`Epilogue`] contract of [`gemm::gemm_fused_into`],
/// bit-identical to [`spgemm`] + [`gemm::activate_words`] +
/// `DecodedPlan::from_words`. NaR operands take the masked slow path
/// (poison, activate, planar refill), exactly like the dense kernel.
pub fn spgemm_fused_into(a: &SparsePlan, b: &DecodedPlan,
                         bias: Option<&[u64]>, epi: Epilogue,
                         cfg: &KernelConfig, out: &mut DecodedPlan) {
    check_shapes_a(a, b, bias);
    let (m, n) = (a.rows, b.cols);
    out.reset(a.fmt, m, n);
    if m == 0 || n == 0 {
        return;
    }
    gemm::record_gemm();
    CTR_SPARSE_GEMMS.fetch_add(1, Ordering::Relaxed);
    gemm::record_fused((m * n) as u64);
    let bd = bias.map(|bs| BiasDec::new(bs, a.fmt));
    let bd_ref = bd.as_ref();
    let (tile, _path, _body) =
        autotune::resolve_sparse(cfg, a.fmt, a.rows, a.cols, a.nnz());
    let eff_k = (a.nnz() / m).max(1);
    let t = gemm::threads_for(m, eff_k, n, cfg);
    let order = nnz_order(a);

    let nar_possible = a.has_nar
        || b.has_nar
        || bd_ref.is_some_and(|d| d.has_nar);
    if nar_possible {
        run_sparse_rows(m, n, &mut out.words, t, tile, Some(&order),
                        &|r, win, s| sparse_row(a, b, bd_ref, r, win,
                                                tile, s),
                        None);
        apply_nar_a(a, b, bd_ref, &mut out.words);
        gemm::activate_words(&mut out.words, epi.act, a.fmt);
        out.refill_planar_from_words();
        return;
    }

    let fmt = a.fmt;
    let act = epi.act;
    let DecodedPlan { words, words8, sig, w, .. } = out;
    let sink = gemm::PlanarSink {
        sig: sig.as_mut_ptr(),
        w: w.as_mut_ptr(),
        w8: if words8.is_empty() {
            std::ptr::null_mut()
        } else {
            words8.as_mut_ptr()
        },
    };
    let hook = move |r0: usize, win: &mut [u64]| {
        // SAFETY: `win` is a row window this job owns exclusively;
        // its planar windows share that exclusivity.
        let (sig_w, w_w, w8_w) =
            unsafe { sink.window(r0 * n, win.len()) };
        simd::epilogue_window(fmt, act, win, sig_w, w_w, w8_w);
    };
    run_sparse_rows(m, n, words, t, tile, Some(&order),
                    &|r, win, s| sparse_row(a, b, bd_ref, r, win,
                                            tile, s),
                    Some(&hook));
}

/// Dense-A × sparse-Bᵀ GEMM [+ bias] — the pruned-weight
/// orientation: `bt` holds the CSR of B's transpose (one compressed
/// row per output column), so `out[i][j] = Σ A[i,kk]·B[kk,j]` walks
/// `bt`'s row `j`. Bit-identical to [`gemm::gemm_with_config`] on
/// the densified B.
pub fn spgemm_bt(a: &DecodedPlan, bt: &SparsePlan,
                 bias: Option<&[u64]>, cfg: &KernelConfig)
                 -> Vec<u64> {
    check_shapes_bt(a, bt, bias);
    let (m, n) = (a.rows, bt.rows);
    if m == 0 || n == 0 {
        return Vec::new();
    }
    gemm::record_gemm();
    CTR_SPARSE_GEMMS.fetch_add(1, Ordering::Relaxed);
    let bd = bias.map(|bs| BiasDec::new(bs, a.fmt));
    let bd_ref = bd.as_ref();
    let (tile, _path, _body) = autotune::resolve_sparse(
        cfg, a.fmt, bt.rows, bt.cols, bt.nnz());
    let eff_k = (bt.nnz() / n).max(1);
    let t = gemm::threads_for(m, eff_k, n, cfg);
    let mut out = vec![0u64; m * n];
    run_sparse_rows(m, n, &mut out, t, tile, None,
                    &|r, win, s| bt_row(a, bt, bd_ref, r, win, s),
                    None);
    apply_nar_bt(a, bt, bd_ref, &mut out);
    out
}

/// Fused [`spgemm_bt`] into a recycled plan buffer — what the fused
/// [`crate::nn::exec::Session`] pipeline calls for layers whose
/// weight density falls below the sparse threshold. Same [`Epilogue`]
/// contract as [`spgemm_fused_into`].
pub fn spgemm_bt_fused_into(a: &DecodedPlan, bt: &SparsePlan,
                            bias: Option<&[u64]>, epi: Epilogue,
                            cfg: &KernelConfig,
                            out: &mut DecodedPlan) {
    check_shapes_bt(a, bt, bias);
    let (m, n) = (a.rows, bt.rows);
    out.reset(a.fmt, m, n);
    if m == 0 || n == 0 {
        return;
    }
    gemm::record_gemm();
    CTR_SPARSE_GEMMS.fetch_add(1, Ordering::Relaxed);
    gemm::record_fused((m * n) as u64);
    let bd = bias.map(|bs| BiasDec::new(bs, a.fmt));
    let bd_ref = bd.as_ref();
    let (tile, _path, _body) = autotune::resolve_sparse(
        cfg, a.fmt, bt.rows, bt.cols, bt.nnz());
    let eff_k = (bt.nnz() / n).max(1);
    let t = gemm::threads_for(m, eff_k, n, cfg);

    let nar_possible = a.has_nar
        || bt.has_nar
        || bd_ref.is_some_and(|d| d.has_nar);
    if nar_possible {
        run_sparse_rows(m, n, &mut out.words, t, tile, None,
                        &|r, win, s| bt_row(a, bt, bd_ref, r, win, s),
                        None);
        apply_nar_bt(a, bt, bd_ref, &mut out.words);
        gemm::activate_words(&mut out.words, epi.act, a.fmt);
        out.refill_planar_from_words();
        return;
    }

    let fmt = a.fmt;
    let act = epi.act;
    let DecodedPlan { words, words8, sig, w, .. } = out;
    let sink = gemm::PlanarSink {
        sig: sig.as_mut_ptr(),
        w: w.as_mut_ptr(),
        w8: if words8.is_empty() {
            std::ptr::null_mut()
        } else {
            words8.as_mut_ptr()
        },
    };
    let hook = move |r0: usize, win: &mut [u64]| {
        // SAFETY: exclusive row window (see spgemm_fused_into).
        let (sig_w, w_w, w8_w) =
            unsafe { sink.window(r0 * n, win.len()) };
        simd::epilogue_window(fmt, act, win, sig_w, w_w, w8_w);
    };
    run_sparse_rows(m, n, words, t, tile, None,
                    &|r, win, s| bt_row(a, bt, bd_ref, r, win, s),
                    Some(&hook));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::{from_f64, P16_FMT, P32_FMT, P8_FMT};
    use crate::util::SplitMix64;

    fn sparse_words(rng: &mut SplitMix64, len: usize, density_pct: u64,
                    fmt: PositFormat) -> Vec<u64> {
        (0..len)
            .map(|_| {
                if rng.below(100) < density_pct {
                    from_f64(rng.wide(-4, 4), fmt)
                } else {
                    0
                }
            })
            .collect()
    }

    #[test]
    fn from_dense_round_trips_through_densify() {
        let mut rng = SplitMix64::new(11);
        for fmt in [P8_FMT, P16_FMT, P32_FMT] {
            for &d in &[0u64, 10, 50, 100] {
                let words = sparse_words(&mut rng, 7 * 9, d, fmt);
                let p = DecodedPlan::from_words(words, 7, 9, fmt);
                let sp = SparsePlan::from_dense(&p);
                let back = sp.densify();
                assert_eq!(back.words, p.words, "{fmt:?} d={d}");
                assert_eq!(back.sig, p.sig);
                assert_eq!(back.w, p.w);
                assert_eq!(sp.nnz(),
                           p.words.iter().filter(|&&w| w != 0).count());
            }
        }
    }

    #[test]
    fn from_dense_transposed_is_the_transpose() {
        let mut rng = SplitMix64::new(12);
        let words = sparse_words(&mut rng, 5 * 8, 40, P16_FMT);
        let p = DecodedPlan::from_words(words, 5, 8, P16_FMT);
        let bt = SparsePlan::from_dense_transposed(&p);
        assert_eq!((bt.rows, bt.cols), (8, 5));
        let back = bt.densify();
        for r in 0..5 {
            for c in 0..8 {
                assert_eq!(back.word(c, r), p.word(r, c),
                           "transpose mismatch at ({r},{c})");
            }
        }
    }

    #[test]
    fn from_csr_validates_structure() {
        let fmt = P8_FMT;
        // Wrong row_ptr length.
        let e = SparsePlan::from_csr(2, 3, vec![0, 1], vec![0],
                                     vec![0x40], fmt)
            .unwrap_err();
        assert!(e.contains("row_ptr"), "{e}");
        // row_ptr must start at 0.
        let e = SparsePlan::from_csr(1, 3, vec![1, 1], vec![],
                                     vec![], fmt)
            .unwrap_err();
        assert!(e.contains("must be 0"), "{e}");
        // Non-monotone row_ptr.
        let e = SparsePlan::from_csr(2, 3, vec![0, 2, 1],
                                     vec![0, 1, 2],
                                     vec![0x40; 3], fmt);
        assert!(e.is_err());
        // Length mismatches.
        let e = SparsePlan::from_csr(1, 3, vec![0, 2], vec![0],
                                     vec![0x40, 0x40], fmt)
            .unwrap_err();
        assert!(e.contains("col_idx"), "{e}");
        let e = SparsePlan::from_csr(1, 3, vec![0, 1], vec![0],
                                     vec![], fmt)
            .unwrap_err();
        assert!(e.contains("words"), "{e}");
        // Out-of-range column.
        let e = SparsePlan::from_csr(1, 3, vec![0, 1], vec![3],
                                     vec![0x40], fmt)
            .unwrap_err();
        assert!(e.contains("out of range"), "{e}");
        // Duplicate column index.
        let e = SparsePlan::from_csr(1, 3, vec![0, 2], vec![1, 1],
                                     vec![0x40, 0x40], fmt)
            .unwrap_err();
        assert!(e.contains("duplicate"), "{e}");
        // Descending column indices.
        let e = SparsePlan::from_csr(1, 3, vec![0, 2], vec![2, 0],
                                     vec![0x40, 0x40], fmt)
            .unwrap_err();
        assert!(e.contains("ascending"), "{e}");
        // A valid plan, including an explicit stored zero.
        let sp = SparsePlan::from_csr(2, 3, vec![0, 2, 2],
                                      vec![0, 2],
                                      vec![0x40, 0x00], fmt)
            .unwrap();
        assert_eq!(sp.nnz(), 2);
        assert_eq!(sp.row_nnz(0), 2);
        assert_eq!(sp.row_nnz(1), 0);
        assert_eq!(sp.sig[1], 0, "explicit zero decodes inert");
    }

    #[test]
    fn from_csr_tracks_nar_per_row() {
        let fmt = P8_FMT;
        let sp = SparsePlan::from_csr(
            2, 2, vec![0, 1, 2], vec![0, 1],
            vec![fmt.nar(), 0x40], fmt)
            .unwrap();
        assert!(sp.has_nar);
        assert_eq!(sp.nar_rows, vec![true, false]);
        assert_eq!(sp.sig[0], 0, "NaR stores sig 0");
    }

    #[test]
    fn row_classes() {
        assert_eq!(classify_row(P16_FMT, 0), RowClass::Empty);
        assert_eq!(classify_row(P16_FMT, 5), RowClass::Direct);
        assert_eq!(classify_row(P16_FMT, lut::P16_CHUNK + 1),
                   RowClass::DeepFold);
        // Only P16 has the i128 headroom bound.
        assert_eq!(classify_row(P8_FMT, lut::P16_CHUNK + 1),
                   RowClass::Direct);
        assert_eq!(classify_row(P32_FMT, lut::P16_CHUNK + 1),
                   RowClass::Direct);
    }

    #[test]
    fn density_and_degenerate_shapes() {
        let p = DecodedPlan::from_words(vec![], 0, 4, P8_FMT);
        let sp = SparsePlan::from_dense(&p);
        assert_eq!(sp.nnz(), 0);
        assert_eq!(sp.density(), 0.0);
        let pb = DecodedPlan::from_words(vec![0x40u64; 12], 4, 3,
                                         P8_FMT);
        assert!(spgemm(&sp, &pb, None).is_empty());
        // Single nonzero.
        let one = SparsePlan::from_csr(3, 4, vec![0, 0, 1, 1],
                                       vec![2], vec![0x40], P8_FMT)
            .unwrap();
        assert_eq!(one.nnz(), 1);
        assert!((one.density() - 1.0 / 12.0).abs() < 1e-12);
        let dense = one.densify();
        let b = DecodedPlan::from_words(vec![0x40u64; 4 * 2], 4, 2,
                                        P8_FMT);
        assert_eq!(spgemm(&one, &b, None),
                   gemm::gemm(&dense, &b, None));
    }

    #[test]
    fn sparse_matches_dense_oracle_quick() {
        // The in-module smoke version of the tests/sparse_gemm.rs
        // sweep: random sparsity, all three formats, bias on/off.
        let mut rng = SplitMix64::new(77);
        for fmt in [P8_FMT, P16_FMT, P32_FMT] {
            for &d in &[0u64, 15, 60, 100] {
                let (m, k, n) = (6, 11, 7);
                let aw = sparse_words(&mut rng, m * k, d, fmt);
                let pa = DecodedPlan::from_words(aw, m, k, fmt);
                let sa = SparsePlan::from_dense(&pa);
                let bw: Vec<u64> = (0..k * n)
                    .map(|_| from_f64(rng.wide(-3, 3), fmt))
                    .collect();
                let pb = DecodedPlan::from_words(bw, k, n, fmt);
                let bias: Vec<u64> = (0..n)
                    .map(|_| from_f64(rng.wide(-2, 2), fmt))
                    .collect();
                for bs in [None, Some(bias.as_slice())] {
                    assert_eq!(spgemm(&sa, &pb, bs),
                               gemm::gemm(&pa, &pb, bs),
                               "{fmt:?} d={d} bias={}", bs.is_some());
                }
                // Bᵀ orientation against the same oracle.
                let bt = SparsePlan::from_dense_transposed(&pb);
                assert_eq!(spgemm_bt(&pa, &bt, Some(&bias),
                                     &settings::current()),
                           gemm::gemm(&pa, &pb, Some(&bias)),
                           "{fmt:?} d={d} bt");
            }
        }
    }

    #[test]
    fn sparse_thread_counts_agree() {
        let mut rng = SplitMix64::new(88);
        let fmt = P16_FMT;
        let (m, k, n) = (13, 9, 11);
        let aw = sparse_words(&mut rng, m * k, 30, fmt);
        let pa = DecodedPlan::from_words(aw, m, k, fmt);
        let sa = SparsePlan::from_dense(&pa);
        let bw: Vec<u64> = (0..k * n)
            .map(|_| from_f64(rng.wide(-3, 3), fmt))
            .collect();
        let pb = DecodedPlan::from_words(bw, k, n, fmt);
        let base = spgemm_with_config(&sa, &pb, None,
                                      &KernelConfig::DEFAULT);
        for threads in [1usize, 2, 3, 8] {
            let cfg = KernelConfig {
                threads: Some(threads),
                ..KernelConfig::DEFAULT
            };
            assert_eq!(spgemm_with_config(&sa, &pb, None, &cfg), base,
                       "threads={threads}");
        }
    }

    #[test]
    fn sparse_counters_move() {
        let fmt = P8_FMT;
        let pa = DecodedPlan::from_words(vec![0x40; 6], 2, 3, fmt);
        let sa = SparsePlan::from_dense(&pa);
        let pb = DecodedPlan::from_words(vec![0x40; 6], 3, 2, fmt);
        let before = gemm::counters();
        let _ = spgemm(&sa, &pb, None);
        let after = gemm::counters();
        // >= : other tests run concurrently and also count.
        assert!(after.sparse_gemms >= before.sparse_gemms + 1);
        assert!(after.gemms >= before.gemms + 1);
    }
}
