//! Decode-once planar compute kernel — the functional hot path.
//!
//! ## Why
//!
//! SPADE's architectural claim (§II) is that a SIMD posit datapath pays
//! the expensive unpack machinery — leading-one detector, complementor,
//! barrel shifter — **once per word**, shared across lanes, rather than
//! once per scalar operation. The original functional path here had the
//! software equivalent of the opposite: every MAC re-ran the full
//! regime/exponent/fraction decode of both operands. This module is the
//! software mirror of the paper's lane-fused datapath, with the decode
//! amortization pushed one level further (PDPU, Li et al. 2023 does the
//! same in RTL for fused dot products):
//!
//! * **Stage 1 (unpack) → [`DecodedPlan`]**: each operand tensor is
//!   decoded *once* into planar (structure-of-arrays) field vectors —
//!   sign-folded significand and LSB exponent. A k-deep GEMM reuses
//!   each decoded element n (or m) times, so per-MAC decode cost goes
//!   to ~zero. For 8/16-bit words decode itself is a table lookup
//!   ([`lut`]); ExPAN(N)D (Nambi et al. 2020) shows P8's 2^16 pair
//!   space makes even full multiply tables practically free, which the
//!   [`lut::p8_prod_lut`] exploits: the whole P8 MAC becomes one
//!   indexed `i64` add.
//! * **Stages 2–3 (multiply + quire) → fused integer MAC**: products of
//!   planar significands accumulate in wide fixed point (`i64` for P8,
//!   `i128` for P16, the 512-bit [`crate::posit::Quire`] via `mac_raw`
//!   for P32) with **no intermediate rounding** — numerically identical
//!   to the quire contract, which `Backend::PositExact` oracles in the
//!   property tests.
//! * **Stages 4–5 (normalize + round) → one `encode_from_parts` per
//!   output**, exactly like the hardware's single Stage-5 rounding.
//! * **Row-block tiling** fans output rows across the persistent
//!   [`pool`] workers ([`gemm::auto_threads`] decides when it pays);
//!   results are bit-identical at any thread count because each output
//!   element's reduction is sequential and exact. The pool's
//!   long-lived, channel-fed threads amortize spawn cost across every
//!   GEMM in the process — the serving hot path issues thousands of
//!   mid-size layer GEMMs per second, where per-call
//!   `std::thread::scope` spawns dominated (the retained
//!   [`gemm::gemm_with_scope`] baseline benches exactly that gap).
//!
//! ## Who uses it
//!
//! [`crate::systolic::gemm::SystolicGemm::run`] (the functional GEMM),
//! [`crate::nn::exec`]'s `Backend::Posit` (with weight plans cached per
//! (layer, mode) in [`crate::nn::exec::Session`]), and the
//! [`crate::coordinator`] sharded planar serving backend all route
//! through [`gemm()`] — coordinator shards submit concurrently and
//! share the one process-wide pool. `benches/hotpath.rs` tracks
//! planar-vs-scalar throughput, thread scaling, and pool-vs-scope
//! dispatch.

pub mod gemm;
pub mod lut;
pub mod plan;
pub mod pool;

pub use gemm::{auto_threads, encode_acc_i128, encode_acc_i64, gemm,
               gemm_with_scope, gemm_with_threads};
pub use lut::{p8_decode_lut, p8_mul, p8_mul_lut, p8_prod_lut,
              p16_decode_lut, DecEntry};
pub use plan::DecodedPlan;
pub use pool::WorkerPool;
