//! Decode-once planar compute kernel — the functional hot path.
//!
//! ## Why
//!
//! SPADE's architectural claim (§II) is that a SIMD posit datapath pays
//! the expensive unpack machinery — leading-one detector, complementor,
//! barrel shifter — **once per word**, shared across lanes, rather than
//! once per scalar operation. This module is the software mirror of the
//! paper's lane-fused datapath, with the decode amortization pushed one
//! level further (PDPU, Li et al. 2023 does the same in RTL for fused
//! dot products):
//!
//! * **Stage 1 (unpack) → [`DecodedPlan`]**: each operand tensor is
//!   decoded *once* into planar (structure-of-arrays) field vectors —
//!   sign-folded significand and LSB exponent (plus a packed byte copy
//!   of the P8 words for the gather loop). A k-deep GEMM reuses each
//!   decoded element n (or m) times, so per-MAC decode cost goes to
//!   ~zero. For 8/16-bit words decode itself is a table lookup
//!   ([`lut`]); ExPAN(N)D (Nambi et al. 2020) shows P8's 2^16 pair
//!   space makes even full multiply tables practically free, which the
//!   [`lut::p8_prod_lut`] exploits: the whole P8 MAC becomes one
//!   indexed `i64` add.
//! * **Stages 2–3 (multiply + quire) → fused integer MAC**: products of
//!   planar significands accumulate in wide fixed point (`i64` for P8,
//!   `i128` for P16, the 512-bit [`crate::posit::Quire`] via `mac_raw`
//!   for P32) with **no intermediate rounding** — numerically identical
//!   to the quire contract, which `Backend::PositExact` oracles in the
//!   property tests.
//! * **Stages 4–5 (normalize + round) → one `encode_from_parts` per
//!   output**, exactly like the hardware's single Stage-5 rounding.
//!
//! ## The tile → panel → lane hierarchy
//!
//! All three precisions route through one loop structure ([`simd`]) —
//! the software analogue of the paper's shared LOD/shifter/multiplier
//! submodules reused across MODEs:
//!
//! ```text
//! tile   a chunk of output rows, claimed off the work-stealing
//!        RowQueue by a persistent pool worker        (pool.rs)
//!  └─ panel   a B-column strip sized for cache residency
//!             (TileConfig::{p16,p32}_panel)          (simd.rs)
//!      └─ k-chunk   reductions deeper than the k-chunk threshold
//!                   (TileConfig::k_chunk_for) stream A and the
//!                   matching B slice in L2-sized chunks, with
//!                   exact partial accumulation per chunk; deep
//!                   P16 folds each exact i128 chunk sum into a
//!                   quire with one mac_raw               (simd.rs)
//!          └─ lane   independent register accumulators:
//!                    P8  — P8_LANES i64 LUT-gather lanes, filled by
//!                          the host's best IsaBody (portable /
//!                          AVX2 ymm / AVX-512 zmm / NEON — detected
//!                          and ranked by isa.rs)
//!                    P16 — P16_MR × P16_NR i128 micro-tile (+ the
//!                          default-off hybrid product LUT)
//!                    P32 — a panel of reused quires      (simd.rs)
//! ```
//!
//! Bit-exactness survives every level because each accumulator is an
//! exact integer (or the exact quire) and integer addition is
//! associative: reordering tiles, panels, or lanes cannot change the
//! final sum, hence not the single rounding either. The identity tests
//! (`tests/kernel_planar.rs`) pin all paths — including the AVX2
//! gather and the retained unblocked baselines — to the
//! `Backend::PositExact` oracle.
//!
//! **Dispatch** carves rows into chunks on a [`pool::RowQueue`];
//! pool workers (and the caller) *steal* chunks until the queue is
//! dry, so NaR-heavy or otherwise uneven rows cannot straggle a fixed
//! split. The pool's long-lived, channel-fed threads amortize spawn
//! cost across every GEMM in the process. [`gemm::gemm_with_scope`]
//! retains the fixed-split per-call-spawn behavior **only** as the
//! bench baseline.
//!
//! ## Tuning knobs (typed config — no environment reads here)
//!
//! Every knob lives in [`settings::KernelConfig`]:
//!
//! | field | effect |
//! |---|---|
//! | [`settings::KernelConfig::threads`] | absolute per-GEMM worker-count override (`None` = size heuristic) |
//! | [`settings::KernelConfig::pool_workers`] | pool size, latched at first pool use (`None` = available parallelism) |
//! | [`settings::KernelConfig::tile`] | explicit tile pin — see [`simd::TileConfig`] (strictly validated); `None` = defaults or autotuned |
//! | [`settings::KernelConfig::path`] | inner-loop shape; `Portable` disables all `std::arch` bodies |
//! | [`settings::KernelConfig::isa`] | explicit [`IsaBody`] pin (`None` = tuned winner, else best detected — see [`isa`]) |
//! | [`settings::KernelConfig::autotune`] | first-use micro-probe autotuning ([`autotune::AutotuneMode`]; default `Off`) |
//!
//! When no tile is pinned and autotuning is enabled, dispatch
//! resolves the geometry through [`autotune`]: a one-time micro-probe
//! per (precision, shape class) picks panel widths, steal/k-chunk
//! depths, the inner path and the ISA body, cached process-wide in
//! [`settings`]. `Engine::warm_up` runs the probes ahead of traffic
//! (and can persist/load the winners — `EngineConfig::tuned_path`).
//!
//! Callers either thread a config explicitly
//! ([`gemm::gemm_with_config`], `Session::set_kernel_config`,
//! `CoordinatorConfig::kernel`) or rely on the installed process
//! default ([`settings::current`]). The old `SPADE_KERNEL_*`
//! environment variables are parsed **once**, at the process edge, by
//! [`crate::api::EngineConfig::from_env`] — the kernel never touches
//! `std::env` (`scripts/verify.sh` enforces this with a grep gate).
//!
//! ## The fused epilogue (decode-once across the network)
//!
//! [`gemm_fused`] / [`gemm_fused_into`] extend the single-rounding
//! contract across layer boundaries: while each output row chunk is
//! still cache-hot, an [`Epilogue`] applies the activation at word
//! level and emits the **planar decoded fields directly**
//! (`simd::epilogue_window`), so layer N's output plan *is* layer
//! N+1's A-operand with zero interior encode/decode round-trip —
//! exactly one rounding per layer output, bit-identical to the
//! layer-wise chain ([`gemm`] → [`relu_words`] →
//! [`DecodedPlan::from_words`]). `gemm_fused_into` recycles a
//! caller-owned plan buffer ([`plan::DecodedPlan::reset`]), so a
//! steady-state fused forward allocates nothing per layer.
//! [`crate::nn::exec::Session`] rides this by default
//! (`SPADE_FUSED=0` / `EngineConfig::fused` is the escape hatch).
//!
//! ## Sparse workloads (CSR SpGEMM)
//!
//! Pruned weights route through [`sparse`]: a [`sparse::SparsePlan`]
//! stores only the nonzeros (CSR `row_ptr`/`col_idx` plus the same
//! planar `sig`/`w` fields, decoded once), and
//! [`sparse::spgemm`] / [`sparse::spgemm_bt`] (+ fused variants)
//! dispatch rows in descending-nnz order over the same work-stealing
//! pool, each row running the accumulator body its length class picks
//! ([`sparse::RowClass`]). The autotuner keys sparse dispatch by a
//! density bucket ([`ShapeClass::Sparse`]). Every sparse result is
//! **bit-identical** to the dense kernel on densified operands —
//! exact accumulators make zero terms true no-ops — gated by
//! `tests/sparse_gemm.rs` and the `sparse_vs_dense` bench section.
//!
//! ## Who uses it
//!
//! [`crate::systolic::gemm::SystolicGemm::run`] (the functional GEMM),
//! [`crate::nn::exec`]'s `Backend::Posit` (fused by default, with
//! weight plans cached per (layer, mode) in
//! [`crate::nn::exec::Session`]), and the [`crate::coordinator`]
//! sharded planar serving backend all route through [`gemm()`] /
//! [`gemm_fused_into`] — coordinator shards submit concurrently and
//! share the one process-wide pool. `benches/hotpath.rs` tracks
//! planar-vs-scalar throughput, lane-vs-scalar-gather and
//! blocked-vs-unblocked inner loops, thread scaling,
//! steal-vs-fixed-split dispatch, and fused-vs-layer-wise forwards
//! (`fused_vs_layerwise`).

pub mod autotune;
pub mod gemm;
pub mod isa;
pub mod lut;
pub mod plan;
pub mod pool;
pub mod settings;
pub mod simd;
pub mod sparse;

pub use autotune::{classify_sparse, AutotuneMode, ShapeClass};
pub use gemm::{activate_words, auto_threads, counters,
               encode_acc_i128, encode_acc_i64, gemm, gemm_fused,
               gemm_fused_into, gemm_single_body, gemm_single_path,
               gemm_with_config, gemm_with_config_stats,
               gemm_with_scope, gemm_with_stats, gemm_with_threads,
               relu_words, Activation, DispatchStats, Dyadic,
               Epilogue, KernelCounters};
pub use isa::{available_bodies, host_has, preferred, IsaBody};
pub use sparse::{classify_row, spgemm, spgemm_bt, spgemm_bt_fused_into,
                 spgemm_fused, spgemm_fused_into, spgemm_with_config,
                 RowClass, SparsePlan};
pub use lut::{p8_decode_lut, p8_mul, p8_mul_lut, p8_prod_lut,
              p16_decode_lut, p16_hyb_lut, DecEntry};
pub use plan::DecodedPlan;
pub use pool::{RowQueue, WorkerPool};
pub use settings::KernelConfig;
pub use simd::{gather_available, InnerPath, TileConfig, K_CHUNK_AUTO,
               K_CHUNK_DEFAULT, P16_MR, P16_NR, P8_LANES};
