//! Planar (structure-of-arrays) decoded tensors.
//!
//! A [`DecodedPlan`] holds a matrix of posit words **decoded once** into
//! parallel field arrays: the sign-folded significand `sig[i]` and the
//! LSB exponent `w[i]` (value = `sig * 2^w`). Every downstream MAC then
//! reads two integers instead of re-running the regime/exponent/fraction
//! unpack — the software analogue of SPADE's shared Stage-1 decode
//! hardware, amortized across the whole tensor instead of per lane-op.
//!
//! Zero encodes as `sig == 0` (it vanishes in products automatically);
//! NaR also stores `sig == 0` and is tracked out of band via the
//! row/column masks, which the GEMM applies as a final poisoning pass —
//! exactly the quire's absorbing-NaR semantics.

use crate::posit::{decode, from_f64, to_f64, PositClass, PositFormat,
                   P16_FMT, P8_FMT};

use super::lut;

/// A posit matrix decoded once into planar field arrays. See module
/// docs.
#[derive(Debug, Clone)]
pub struct DecodedPlan {
    /// Posit format of every element.
    pub fmt: PositFormat,
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// The quantized posit words (row-major) — kept for the P8
    /// product-LUT path and for re-encoding-free round trips.
    pub words: Vec<u64>,
    /// Packed byte copy of `words` for 8-bit formats (empty for wider
    /// formats). The lane-fused P8 kernel indexes its product LUT
    /// through these: one byte per element keeps a k-deep B panel 8×
    /// smaller in cache than the `u64` words, and gives the AVX2
    /// gather path contiguous `u8` lanes to zero-extend.
    pub words8: Vec<u8>,
    /// Sign-folded significands (0 for zero and NaR).
    pub sig: Vec<i64>,
    /// LSB exponents (`scale - fbits`): value = `sig * 2^w`.
    pub w: Vec<i32>,
    /// True if any element is NaR.
    pub has_nar: bool,
    /// Per-row NaR mask (empty unless `has_nar`).
    pub nar_rows: Vec<bool>,
    /// Per-column NaR mask (empty unless `has_nar`).
    pub nar_cols: Vec<bool>,
}

impl DecodedPlan {
    /// Decode a row-major word matrix. For 8/16-bit formats the decode
    /// runs through the lazily-built LUTs; wider formats decode
    /// directly (a 2^32-entry table is not worth its memory).
    pub fn from_words(words: Vec<u64>, rows: usize, cols: usize,
                      fmt: PositFormat) -> DecodedPlan {
        assert_eq!(words.len(), rows * cols,
                   "plan shape {rows}x{cols} vs {} words", words.len());
        // Canonicalize to the low nbits (the LUT paths index by word).
        let words: Vec<u64> =
            words.into_iter().map(|w| w & fmt.mask()).collect();
        let words8: Vec<u8> = if fmt.nbits <= 8 {
            words.iter().map(|&w| w as u8).collect()
        } else {
            Vec::new()
        };
        let len = words.len();
        let mut sig = Vec::with_capacity(len);
        let mut w = Vec::with_capacity(len);
        let mut has_nar = false;
        let mut nar_rows: Vec<bool> = Vec::new();
        let mut nar_cols: Vec<bool> = Vec::new();

        let nar_at = |idx: usize, nr: &mut Vec<bool>,
                          nc: &mut Vec<bool>, seen: &mut bool| {
            if !*seen {
                *seen = true;
                *nr = vec![false; rows];
                *nc = vec![false; cols];
            }
            nr[idx / cols] = true;
            nc[idx % cols] = true;
        };

        // LUT fast paths apply only to the exact standard formats the
        // tables were built for; any other (nbits, es) combination —
        // PositFormat is freely constructible — decodes generically.
        if fmt == P8_FMT || fmt == P16_FMT {
            let t = if fmt == P8_FMT {
                lut::p8_decode_lut()
            } else {
                lut::p16_decode_lut()
            };
            for (idx, &word) in words.iter().enumerate() {
                let e = t[word as usize];
                sig.push(e.sig as i64);
                w.push(e.w as i32);
                if e.nar {
                    nar_at(idx, &mut nar_rows, &mut nar_cols,
                           &mut has_nar);
                }
            }
        } else {
            for (idx, &word) in words.iter().enumerate() {
                let d = decode(word, fmt);
                match d.class {
                    PositClass::Zero => {
                        sig.push(0);
                        w.push(0);
                    }
                    PositClass::NaR => {
                        sig.push(0);
                        w.push(0);
                        nar_at(idx, &mut nar_rows, &mut nar_cols,
                               &mut has_nar);
                    }
                    PositClass::Normal => {
                        let s = d.significand() as i64;
                        sig.push(if d.sign { -s } else { s });
                        w.push(d.scale - d.fbits as i32);
                    }
                }
            }
        }

        DecodedPlan { fmt, rows, cols, words, words8, sig, w, has_nar,
                      nar_rows, nar_cols }
    }

    /// Quantize an f64 matrix to `fmt` and decode it (one pass).
    pub fn from_f64(data: &[f64], rows: usize, cols: usize,
                    fmt: PositFormat) -> DecodedPlan {
        let words = data.iter().map(|&v| from_f64(v, fmt)).collect();
        Self::from_words(words, rows, cols, fmt)
    }

    /// Quantize an f32 matrix to `fmt` and decode it.
    pub fn from_f32(data: &[f32], rows: usize, cols: usize,
                    fmt: PositFormat) -> DecodedPlan {
        let words =
            data.iter().map(|&v| from_f64(v as f64, fmt)).collect();
        Self::from_words(words, rows, cols, fmt)
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// True if the plan has no elements.
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Word at (row, col).
    #[inline]
    pub fn word(&self, r: usize, c: usize) -> u64 {
        self.words[r * self.cols + c]
    }

    /// Decode back to f64 values (NaR → NaN).
    pub fn to_f64(&self) -> Vec<f64> {
        self.words.iter().map(|&wd| to_f64(wd, self.fmt)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::{P16_FMT, P32_FMT, P8_FMT};
    use crate::util::SplitMix64;

    #[test]
    fn planar_fields_reconstruct_values() {
        // sig * 2^w must equal the decoded value for every word, all
        // three formats (p32 sampled).
        for fmt in [P8_FMT, P16_FMT] {
            for word in 0..(1u64 << fmt.nbits) {
                let p = DecodedPlan::from_words(vec![word], 1, 1, fmt);
                let v = to_f64(word, fmt);
                if word == fmt.nar() {
                    assert!(p.has_nar && p.sig[0] == 0);
                    continue;
                }
                let mine = p.sig[0] as f64
                    * f64::from_bits(((1023 + p.w[0] as i64) as u64)
                                     << 52);
                assert_eq!(mine, v, "{fmt:?} {word:#x}");
            }
        }
        let mut rng = SplitMix64::new(91);
        for _ in 0..50_000 {
            let word = rng.next_u64() & P32_FMT.mask();
            if word == P32_FMT.nar() {
                continue;
            }
            let p = DecodedPlan::from_words(vec![word], 1, 1, P32_FMT);
            let v = to_f64(word, P32_FMT);
            let mine = p.sig[0] as f64
                * f64::from_bits(((1023 + p.w[0] as i64) as u64) << 52);
            assert_eq!(mine, v, "{word:#x}");
        }
    }

    #[test]
    fn nar_masks_mark_rows_and_cols() {
        let fmt = P8_FMT;
        let words = vec![0x40, 0x80, 0x40,
                         0x40, 0x40, 0x40]; // NaR at (0, 1)
        let p = DecodedPlan::from_words(words, 2, 3, fmt);
        assert!(p.has_nar);
        assert_eq!(p.nar_rows, vec![true, false]);
        assert_eq!(p.nar_cols, vec![false, true, false]);
    }

    #[test]
    fn packed_bytes_mirror_words_for_p8() {
        let words: Vec<u64> = (0..256).collect();
        let p = DecodedPlan::from_words(words, 16, 16, P8_FMT);
        assert_eq!(p.words8.len(), 256);
        assert!(p
            .words8
            .iter()
            .zip(&p.words)
            .all(|(&b, &w)| b as u64 == w));
        // wider formats skip the packed copy
        let p16 = DecodedPlan::from_words(vec![0u64; 4], 2, 2, P16_FMT);
        assert!(p16.words8.is_empty());
    }

    #[test]
    fn quantize_round_trip() {
        let fmt = P16_FMT;
        let vals = [0.0, 1.5, -2.25, 100.0, 1e-4];
        let p = DecodedPlan::from_f64(&vals, 1, 5, fmt);
        let back = p.to_f64();
        for (v, b) in vals.iter().zip(&back) {
            assert_eq!(*b, to_f64(from_f64(*v, fmt), fmt));
        }
        assert!(!p.has_nar && p.nar_rows.is_empty());
    }
}
