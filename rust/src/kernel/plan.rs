//! Planar (structure-of-arrays) decoded tensors.
//!
//! A [`DecodedPlan`] holds a matrix of posit words **decoded once** into
//! parallel field arrays: the sign-folded significand `sig[i]` and the
//! LSB exponent `w[i]` (value = `sig * 2^w`). Every downstream MAC then
//! reads two integers instead of re-running the regime/exponent/fraction
//! unpack — the software analogue of SPADE's shared Stage-1 decode
//! hardware, amortized across the whole tensor instead of per lane-op.
//!
//! Zero encodes as `sig == 0` (it vanishes in products automatically);
//! NaR also stores `sig == 0` and is tracked out of band via the
//! row/column masks, which the GEMM applies as a final poisoning pass —
//! exactly the quire's absorbing-NaR semantics.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::posit::{decode, from_f64, to_f64, PositClass, PositFormat,
                   P16_FMT, P8_FMT};

use super::lut;

/// Elements decoded word → planar by [`DecodedPlan::from_words`] (and
/// the fused GEMM's NaR slow path) since process start. The fused
/// pipeline's whole point is that this stays flat between the input
/// edge and the logits — `tests/fused_pipeline.rs` asserts it.
static CTR_PLAN_DECODES: AtomicU64 = AtomicU64::new(0);

/// Elements quantized (encoded) float → posit by
/// [`DecodedPlan::from_f64`] / [`DecodedPlan::from_f32`] since process
/// start. On the fused path only the network input edge pays this.
static CTR_PLAN_ENCODES: AtomicU64 = AtomicU64::new(0);

/// Process-wide decode-element counter (see [`CTR_PLAN_DECODES`]).
pub(super) fn plan_decodes() -> u64 {
    CTR_PLAN_DECODES.load(Ordering::Relaxed)
}

/// Process-wide encode-element counter (see [`CTR_PLAN_ENCODES`]).
pub(super) fn plan_encodes() -> u64 {
    CTR_PLAN_ENCODES.load(Ordering::Relaxed)
}

/// A posit matrix decoded once into planar field arrays. See module
/// docs.
#[derive(Debug, Clone)]
pub struct DecodedPlan {
    /// Posit format of every element.
    pub fmt: PositFormat,
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// The quantized posit words (row-major) — kept for the P8
    /// product-LUT path and for re-encoding-free round trips.
    pub words: Vec<u64>,
    /// Packed byte copy of `words` for 8-bit formats (empty for wider
    /// formats). The lane-fused P8 kernel indexes its product LUT
    /// through these: one byte per element keeps a k-deep B panel 8×
    /// smaller in cache than the `u64` words, and gives the AVX2
    /// gather path contiguous `u8` lanes to zero-extend.
    pub words8: Vec<u8>,
    /// Sign-folded significands (0 for zero and NaR).
    pub sig: Vec<i64>,
    /// LSB exponents (`scale - fbits`): value = `sig * 2^w`.
    pub w: Vec<i32>,
    /// True if any element is NaR.
    pub has_nar: bool,
    /// Per-row NaR mask (empty unless `has_nar`).
    pub nar_rows: Vec<bool>,
    /// Per-column NaR mask (empty unless `has_nar`).
    pub nar_cols: Vec<bool>,
}

impl DecodedPlan {
    /// Decode a row-major word matrix. For 8/16-bit formats the decode
    /// runs through the lazily-built LUTs; wider formats decode
    /// directly (a 2^32-entry table is not worth its memory).
    pub fn from_words(words: Vec<u64>, rows: usize, cols: usize,
                      fmt: PositFormat) -> DecodedPlan {
        assert_eq!(words.len(), rows * cols,
                   "plan shape {rows}x{cols} vs {} words", words.len());
        // Canonicalize to the low nbits (the LUT paths index by word).
        let words: Vec<u64> =
            words.into_iter().map(|w| w & fmt.mask()).collect();
        let words8: Vec<u8> = if fmt.nbits <= 8 {
            words.iter().map(|&w| w as u8).collect()
        } else {
            Vec::new()
        };
        let len = words.len();
        CTR_PLAN_DECODES.fetch_add(len as u64, Ordering::Relaxed);
        let mut sig = Vec::with_capacity(len);
        let mut w = Vec::with_capacity(len);
        let mut has_nar = false;
        let mut nar_rows: Vec<bool> = Vec::new();
        let mut nar_cols: Vec<bool> = Vec::new();

        let nar_at = |idx: usize, nr: &mut Vec<bool>,
                          nc: &mut Vec<bool>, seen: &mut bool| {
            if !*seen {
                *seen = true;
                *nr = vec![false; rows];
                *nc = vec![false; cols];
            }
            nr[idx / cols] = true;
            nc[idx % cols] = true;
        };

        // LUT fast paths apply only to the exact standard formats the
        // tables were built for; any other (nbits, es) combination —
        // PositFormat is freely constructible — decodes generically.
        if fmt == P8_FMT || fmt == P16_FMT {
            let t = if fmt == P8_FMT {
                lut::p8_decode_lut()
            } else {
                lut::p16_decode_lut()
            };
            for (idx, &word) in words.iter().enumerate() {
                let e = t[word as usize];
                sig.push(e.sig as i64);
                w.push(e.w as i32);
                if e.nar {
                    nar_at(idx, &mut nar_rows, &mut nar_cols,
                           &mut has_nar);
                }
            }
        } else {
            for (idx, &word) in words.iter().enumerate() {
                let d = decode(word, fmt);
                match d.class {
                    PositClass::Zero => {
                        sig.push(0);
                        w.push(0);
                    }
                    PositClass::NaR => {
                        sig.push(0);
                        w.push(0);
                        nar_at(idx, &mut nar_rows, &mut nar_cols,
                               &mut has_nar);
                    }
                    PositClass::Normal => {
                        let s = d.significand() as i64;
                        sig.push(if d.sign { -s } else { s });
                        w.push(d.scale - d.fbits as i32);
                    }
                }
            }
        }

        DecodedPlan { fmt, rows, cols, words, words8, sig, w, has_nar,
                      nar_rows, nar_cols }
    }

    /// Quantize an f64 matrix to `fmt` and decode it (one pass).
    pub fn from_f64(data: &[f64], rows: usize, cols: usize,
                    fmt: PositFormat) -> DecodedPlan {
        CTR_PLAN_ENCODES.fetch_add(data.len() as u64,
                                   Ordering::Relaxed);
        let words = data.iter().map(|&v| from_f64(v, fmt)).collect();
        Self::from_words(words, rows, cols, fmt)
    }

    /// Quantize an f32 matrix to `fmt` and decode it.
    pub fn from_f32(data: &[f32], rows: usize, cols: usize,
                    fmt: PositFormat) -> DecodedPlan {
        CTR_PLAN_ENCODES.fetch_add(data.len() as u64,
                                   Ordering::Relaxed);
        let words =
            data.iter().map(|&v| from_f64(v as f64, fmt)).collect();
        Self::from_words(words, rows, cols, fmt)
    }

    /// Adopt planar fields produced elsewhere (e.g. by the fused GEMM
    /// epilogue) **without decoding anything**: `sig`/`w` are trusted
    /// to match `words`, and only the cheap derived fields (packed P8
    /// bytes, NaR masks — a word scan, not a field unpack) are
    /// rebuilt. This is the constructor that lets layer N's fused
    /// output become layer N+1's A-operand with zero encode/decode
    /// round-trip; neither the decode nor the encode counter moves.
    pub fn from_planar(words: Vec<u64>, sig: Vec<i64>, w: Vec<i32>,
                       rows: usize, cols: usize, fmt: PositFormat)
                       -> DecodedPlan {
        assert_eq!(words.len(), rows * cols,
                   "planar shape {rows}x{cols} vs {} words",
                   words.len());
        assert_eq!(sig.len(), words.len(), "sig length");
        assert_eq!(w.len(), words.len(), "w length");
        let mut p = DecodedPlan { fmt, rows, cols, words,
                                  words8: Vec::new(), sig, w,
                                  has_nar: false,
                                  nar_rows: Vec::new(),
                                  nar_cols: Vec::new() };
        p.finish_fill();
        p
    }

    /// An empty plan to be filled later via [`DecodedPlan::reset`] —
    /// the seed of a reusable inter-layer ping-pong buffer.
    pub fn empty(fmt: PositFormat) -> DecodedPlan {
        DecodedPlan { fmt, rows: 0, cols: 0, words: Vec::new(),
                      words8: Vec::new(), sig: Vec::new(),
                      w: Vec::new(), has_nar: false,
                      nar_rows: Vec::new(), nar_cols: Vec::new() }
    }

    /// Re-shape this plan into a zeroed `rows`×`cols` matrix of `fmt`,
    /// **retaining every buffer's capacity**: in steady state a fused
    /// forward pass cycles a few of these buffers and allocates
    /// nothing per layer. All elements become posit zero and the NaR
    /// masks are cleared; producers fill `words`/`sig`/`w` (and call
    /// [`DecodedPlan::finish_fill`] if NaR words may be present).
    pub fn reset(&mut self, fmt: PositFormat, rows: usize,
                 cols: usize) {
        let len = rows * cols;
        self.fmt = fmt;
        self.rows = rows;
        self.cols = cols;
        self.words.clear();
        self.words.resize(len, 0);
        self.sig.clear();
        self.sig.resize(len, 0);
        self.w.clear();
        self.w.resize(len, 0);
        self.words8.clear();
        if fmt.nbits <= 8 {
            self.words8.resize(len, 0);
        }
        self.has_nar = false;
        self.nar_rows.clear();
        self.nar_cols.clear();
    }

    /// Rebuild the derived fields after `words`/`sig`/`w` were filled
    /// externally: the packed P8 byte copy and the NaR row/column
    /// masks (a literal word scan — no field decode).
    pub fn finish_fill(&mut self) {
        self.words8.clear();
        if self.fmt.nbits <= 8 {
            self.words8
                .extend(self.words.iter().map(|&w| w as u8));
        }
        self.rescan_nar();
    }

    /// Rebuild `has_nar` and the row/column masks from the words.
    fn rescan_nar(&mut self) {
        let nar = self.fmt.nar();
        self.has_nar = false;
        self.nar_rows.clear();
        self.nar_cols.clear();
        for (idx, &wd) in self.words.iter().enumerate() {
            if wd == nar {
                if !self.has_nar {
                    self.has_nar = true;
                    self.nar_rows.resize(self.rows, false);
                    self.nar_cols.resize(self.cols, false);
                }
                self.nar_rows[idx / self.cols] = true;
                self.nar_cols[idx % self.cols] = true;
            }
        }
    }

    /// Reinterpret the same row-major elements under a new
    /// `rows`×`cols` geometry (the planar flatten: element order is
    /// unchanged, only the matrix view — and therefore the NaR masks —
    /// change).
    pub fn reshape(&mut self, rows: usize, cols: usize) {
        assert_eq!(rows * cols, self.words.len(),
                   "reshape {rows}x{cols} vs {} elements",
                   self.words.len());
        if rows == self.rows && cols == self.cols {
            return;
        }
        self.rows = rows;
        self.cols = cols;
        if self.has_nar {
            self.rescan_nar();
        }
    }

    /// Re-round every element into `fmt` — the one *genuine* extra
    /// rounding a mixed-precision policy transition requires. Exact:
    /// every ≤32-bit posit value is exactly representable in f64, so
    /// the only rounding is the quantization into the new format
    /// (NaR → NaN → NaR round-trips). Same-format requantization is
    /// the identity (a plain clone).
    pub fn requantize(&self, fmt: PositFormat) -> DecodedPlan {
        if fmt == self.fmt {
            return self.clone();
        }
        DecodedPlan::from_f64(&self.to_f64(), self.rows, self.cols,
                              fmt)
    }

    /// Decode the planar loop of the fused GEMM's NaR slow path: the
    /// front end wrote (possibly poisoned) words into `self.words`;
    /// rebuild `sig`/`w` and the derived fields from them in place.
    /// Counts as a planar decode (it is one).
    pub(super) fn refill_planar_from_words(&mut self) {
        CTR_PLAN_DECODES.fetch_add(self.words.len() as u64,
                                   Ordering::Relaxed);
        if self.fmt == P8_FMT || self.fmt == P16_FMT {
            let t = if self.fmt == P8_FMT {
                lut::p8_decode_lut()
            } else {
                lut::p16_decode_lut()
            };
            for (i, &wd) in self.words.iter().enumerate() {
                let e = &t[wd as usize];
                self.sig[i] = e.sig as i64;
                self.w[i] = e.w as i32;
            }
        } else {
            for (i, &wd) in self.words.iter().enumerate() {
                let d = decode(wd, self.fmt);
                match d.class {
                    PositClass::Zero | PositClass::NaR => {
                        self.sig[i] = 0;
                        self.w[i] = 0;
                    }
                    PositClass::Normal => {
                        let s = d.significand() as i64;
                        self.sig[i] = if d.sign { -s } else { s };
                        self.w[i] = d.scale - d.fbits as i32;
                    }
                }
            }
        }
        self.finish_fill();
    }

    /// Exact f64 value of element `idx` straight from the planar
    /// fields — `sig * 2^w`, no word decode (NaR → NaN). This is what
    /// lets max-pool select winners on a plan without ever leaving
    /// planar form.
    #[inline]
    pub fn value(&self, idx: usize) -> f64 {
        if self.words[idx] == self.fmt.nar() {
            return f64::NAN;
        }
        self.sig[idx] as f64
            * f64::from_bits(((1023 + self.w[idx] as i64) as u64)
                             << 52)
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// True if the plan has no elements.
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Word at (row, col).
    #[inline]
    pub fn word(&self, r: usize, c: usize) -> u64 {
        self.words[r * self.cols + c]
    }

    /// Decode back to f64 values (NaR → NaN).
    pub fn to_f64(&self) -> Vec<f64> {
        self.words.iter().map(|&wd| to_f64(wd, self.fmt)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::{P16_FMT, P32_FMT, P8_FMT};
    use crate::util::SplitMix64;

    #[test]
    fn planar_fields_reconstruct_values() {
        // sig * 2^w must equal the decoded value for every word, all
        // three formats (p32 sampled).
        for fmt in [P8_FMT, P16_FMT] {
            for word in 0..(1u64 << fmt.nbits) {
                let p = DecodedPlan::from_words(vec![word], 1, 1, fmt);
                let v = to_f64(word, fmt);
                if word == fmt.nar() {
                    assert!(p.has_nar && p.sig[0] == 0);
                    continue;
                }
                let mine = p.sig[0] as f64
                    * f64::from_bits(((1023 + p.w[0] as i64) as u64)
                                     << 52);
                assert_eq!(mine, v, "{fmt:?} {word:#x}");
            }
        }
        let mut rng = SplitMix64::new(91);
        for _ in 0..50_000 {
            let word = rng.next_u64() & P32_FMT.mask();
            if word == P32_FMT.nar() {
                continue;
            }
            let p = DecodedPlan::from_words(vec![word], 1, 1, P32_FMT);
            let v = to_f64(word, P32_FMT);
            let mine = p.sig[0] as f64
                * f64::from_bits(((1023 + p.w[0] as i64) as u64) << 52);
            assert_eq!(mine, v, "{word:#x}");
        }
    }

    #[test]
    fn nar_masks_mark_rows_and_cols() {
        let fmt = P8_FMT;
        let words = vec![0x40, 0x80, 0x40,
                         0x40, 0x40, 0x40]; // NaR at (0, 1)
        let p = DecodedPlan::from_words(words, 2, 3, fmt);
        assert!(p.has_nar);
        assert_eq!(p.nar_rows, vec![true, false]);
        assert_eq!(p.nar_cols, vec![false, true, false]);
    }

    #[test]
    fn packed_bytes_mirror_words_for_p8() {
        let words: Vec<u64> = (0..256).collect();
        let p = DecodedPlan::from_words(words, 16, 16, P8_FMT);
        assert_eq!(p.words8.len(), 256);
        assert!(p
            .words8
            .iter()
            .zip(&p.words)
            .all(|(&b, &w)| b as u64 == w));
        // wider formats skip the packed copy
        let p16 = DecodedPlan::from_words(vec![0u64; 4], 2, 2, P16_FMT);
        assert!(p16.words8.is_empty());
    }

    #[test]
    fn from_planar_adopts_fields_without_decode() {
        let fmt = P8_FMT;
        let words: Vec<u64> = (0..=255u64).collect();
        let base = DecodedPlan::from_words(words, 16, 16, fmt);
        let before = plan_decodes();
        let p = DecodedPlan::from_planar(base.words.clone(),
                                         base.sig.clone(),
                                         base.w.clone(), 16, 16, fmt);
        assert_eq!(plan_decodes(), before,
                   "from_planar must not decode");
        assert_eq!(p.words, base.words);
        assert_eq!(p.sig, base.sig);
        assert_eq!(p.w, base.w);
        assert_eq!(p.words8, base.words8);
        assert_eq!(p.has_nar, base.has_nar);
        assert_eq!(p.nar_rows, base.nar_rows);
        assert_eq!(p.nar_cols, base.nar_cols);
    }

    #[test]
    fn reset_reuses_buffer_capacity() {
        let mut p = DecodedPlan::empty(P16_FMT);
        p.reset(P16_FMT, 8, 8);
        assert_eq!(p.len(), 64);
        assert!(p.words.iter().all(|&w| w == 0));
        let ptr = p.words.as_ptr();
        let cap = p.words.capacity();
        // Same-or-smaller shape: the buffers must not reallocate.
        p.reset(P16_FMT, 4, 8);
        assert_eq!(p.words.as_ptr(), ptr);
        assert_eq!(p.words.capacity(), cap);
        assert_eq!((p.rows, p.cols), (4, 8));
        // Format switch re-derives the packed byte copy.
        p.reset(P8_FMT, 2, 3);
        assert_eq!(p.words8.len(), 6);
        assert!(!p.has_nar && p.nar_rows.is_empty());
    }

    #[test]
    fn requantize_re_rounds_exactly_once() {
        let vals = [0.0, 1.5, -2.25, 100.0, 1e-4, -0.37];
        let p16 = DecodedPlan::from_f64(&vals, 2, 3, P16_FMT);
        let p8 = p16.requantize(P8_FMT);
        // Must equal quantizing the exact P16 values directly to P8.
        let want = DecodedPlan::from_f64(&p16.to_f64(), 2, 3, P8_FMT);
        assert_eq!(p8.words, want.words);
        // Same format: identity.
        let same = p16.requantize(P16_FMT);
        assert_eq!(same.words, p16.words);
        // NaR survives the transition.
        let nar = DecodedPlan::from_words(vec![P32_FMT.nar()], 1, 1,
                                          P32_FMT);
        let rq = nar.requantize(P8_FMT);
        assert!(rq.has_nar && rq.words[0] == P8_FMT.nar());
    }

    #[test]
    fn planar_value_matches_word_decode() {
        for fmt in [P8_FMT, P16_FMT] {
            let words: Vec<u64> = (0..(1u64 << fmt.nbits)).collect();
            let len = words.len();
            let p = DecodedPlan::from_words(words, 1, len, fmt);
            for idx in 0..len {
                let v = p.value(idx);
                let want = to_f64(p.words[idx], fmt);
                if want.is_nan() {
                    assert!(v.is_nan());
                } else {
                    assert_eq!(v, want, "{fmt:?} idx {idx}");
                }
            }
        }
    }

    #[test]
    fn reshape_rebuilds_nar_masks() {
        let fmt = P8_FMT;
        let words = vec![0x40, 0x80, 0x40,
                         0x40, 0x40, 0x40]; // NaR at (0, 1)
        let mut p = DecodedPlan::from_words(words, 2, 3, fmt);
        p.reshape(3, 2); // NaR now at (0, 1) of a 3x2 view
        assert_eq!(p.nar_rows, vec![true, false, false]);
        assert_eq!(p.nar_cols, vec![false, true]);
    }

    #[test]
    fn quantize_round_trip() {
        let fmt = P16_FMT;
        let vals = [0.0, 1.5, -2.25, 100.0, 1e-4];
        let p = DecodedPlan::from_f64(&vals, 1, 5, fmt);
        let back = p.to_f64();
        for (v, b) in vals.iter().zip(&back) {
            assert_eq!(*b, to_f64(from_f64(*v, fmt), fmt));
        }
        assert!(!p.has_nar && p.nar_rows.is_empty());
    }
}
