//! Runtime ISA feature detection and per-host kernel-body dispatch.
//!
//! SPADE's architectural claim is one lane-fused datapath reused
//! across precisions; the software mirror of that claim is one
//! *dispatch point* reused across instruction sets. This module is
//! that point: it centralizes every runtime CPU-feature probe the
//! kernel performs and names the hand-written inner-loop bodies as a
//! small closed enum, [`IsaBody`], that the rest of the tree treats
//! as data — the autotuner sweeps it as a candidate axis, the tuned
//! table persists it as a string tag, and `SPADE_KERNEL_ISA` pins it
//! from the environment (through [`crate::api::env`] only, like every
//! other knob).
//!
//! ## The bodies
//!
//! | body | ISA | what it is |
//! |---|---|---|
//! | [`IsaBody::Portable`] | any | scalar lane loop (and the autovectorized chunked k-loop) |
//! | [`IsaBody::Avx2`] | x86-64 AVX2 | ymm `vpgatherqq` P8 product-LUT gather, 8 lanes/step |
//! | [`IsaBody::Avx512`] | x86-64 AVX-512F | zmm `vpgatherqq` P8 gather, 16 lanes/step |
//! | [`IsaBody::Neon`] | aarch64 NEON | 128-bit table-gather P8 body, 8 lanes/step |
//!
//! Every body accumulates the same exact `i64` products from the same
//! P8 product LUT and finishes through the same single
//! `encode_acc_i64` rounding, so they are bit-identical to the scalar
//! quire oracle by the associativity contract (integer addition is
//! associative; reordering lanes cannot change the exact sum, hence
//! not the rounding either). `rust/tests/isa_bodies.rs` force-runs
//! every compiled-in body against the oracle.
//!
//! ## Detection → candidate grid → persisted winners
//!
//! [`host_has`] answers "can this process run body X right now"
//! (cached after the first query — feature detection is a CPUID read,
//! but the kernel asks per GEMM). [`available_bodies`] lists the
//! host's bodies best-first and [`preferred`] names the default
//! choice. The autotuner ([`crate::kernel::autotune`]) widens its P8
//! candidate grids over `available_bodies()` so `Engine::warm_up`
//! probes (precision, shape class, body) triples and installs the
//! measured winner per host; `EngineConfig::tuned_path` then persists
//! those winners as `spade-tuned-v1` JSON so a fleet of identical
//! machines probes once, not per process. Entries naming a body the
//! loading host lacks are skipped (and re-probed) rather than trusted.
//!
//! ## Hygiene
//!
//! `is_x86_feature_detected!` / `std::arch` use is confined to this
//! module and [`crate::kernel::simd`] (where the intrinsic bodies
//! live) by the `spade-lint` `isa-hygiene` rule — a feature check
//! anywhere else would fragment the dispatch decision this module
//! exists to centralize.

use std::sync::OnceLock;

/// A hand-written kernel inner-loop body, named as data.
///
/// `Portable` is always available; the rest require the matching ISA
/// at runtime ([`host_has`]). The enum is deliberately closed and
/// `Copy` so configs, tuned-table entries, and autotune candidates
/// can carry a body by value.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum IsaBody {
    /// Scalar lane loop; the universal fallback and the body the
    /// chunked k-loop autovectorizes from.
    Portable,
    /// AVX2 ymm `vpgatherqq` product-LUT gather (8 P8 lanes/step).
    Avx2,
    /// AVX-512F zmm `vpgatherqq` gather (16 P8 lanes/step — two zmm
    /// index/result pairs per iteration).
    Avx512,
    /// aarch64 NEON 128-bit table-gather body (8 P8 lanes/step).
    Neon,
}

impl IsaBody {
    /// Every compiled-in body, in declaration order (not preference
    /// order — see [`available_bodies`] for best-first).
    pub const ALL: [IsaBody; 4] =
        [IsaBody::Portable, IsaBody::Avx2, IsaBody::Avx512,
         IsaBody::Neon];

    /// Stable string tag used by `SPADE_KERNEL_ISA`, config JSON, the
    /// tuned-table schema, and bench keys.
    pub fn tag(self) -> &'static str {
        match self {
            IsaBody::Portable => "portable",
            IsaBody::Avx2 => "avx2",
            IsaBody::Avx512 => "avx512",
            IsaBody::Neon => "neon",
        }
    }

    /// Inverse of [`tag`](Self::tag). Strict: unknown tags are an
    /// error naming the full grammar, like every other engine knob.
    pub fn from_tag(s: &str) -> Result<IsaBody, String> {
        match s {
            "portable" => Ok(IsaBody::Portable),
            "avx2" => Ok(IsaBody::Avx2),
            "avx512" => Ok(IsaBody::Avx512),
            "neon" => Ok(IsaBody::Neon),
            other => Err(format!(
                "unknown ISA body {other:?} (expected auto, \
                 portable, avx2, avx512, or neon)")),
        }
    }
}

/// Cached result of the one-time host feature probe.
struct HostIsa {
    avx2: bool,
    avx512: bool,
    neon: bool,
}

fn host() -> &'static HostIsa {
    static HOST: OnceLock<HostIsa> = OnceLock::new();
    HOST.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            // The zmm body (and the `avx512f` detection macro itself)
            // needs Rust ≥ 1.89; `build.rs` probes the toolchain and
            // sets `spade_avx512`. Without it the body is not
            // compiled, so detection must say "no" too.
            #[cfg(spade_avx512)]
            let avx512 = is_x86_feature_detected!("avx512f");
            #[cfg(not(spade_avx512))]
            let avx512 = false;
            HostIsa {
                avx2: is_x86_feature_detected!("avx2"),
                avx512,
                neon: false,
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            // NEON (ASIMD) is architecturally mandatory on aarch64.
            HostIsa { avx2: false, avx512: false, neon: true }
        }
        #[cfg(not(any(target_arch = "x86_64",
                      target_arch = "aarch64")))]
        {
            HostIsa { avx2: false, avx512: false, neon: false }
        }
    })
}

/// Can this host execute `body` right now? `Portable` is always
/// `true`; the rest reflect the cached runtime feature probe.
pub fn host_has(body: IsaBody) -> bool {
    match body {
        IsaBody::Portable => true,
        IsaBody::Avx2 => host().avx2,
        IsaBody::Avx512 => host().avx512,
        IsaBody::Neon => host().neon,
    }
}

/// The host's available bodies, best-first (widest gather first,
/// `Portable` always last). This is the autotuner's sweep order and
/// the order the forced-body test names bodies in.
pub fn available_bodies() -> Vec<IsaBody> {
    let mut out = Vec::with_capacity(4);
    for b in [IsaBody::Avx512, IsaBody::Avx2, IsaBody::Neon] {
        if host_has(b) {
            out.push(b);
        }
    }
    out.push(IsaBody::Portable);
    out
}

/// The body dispatch uses when nothing pins or tunes one: the best
/// the host has.
pub fn preferred() -> IsaBody {
    available_bodies()[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_round_trip_and_reject_junk() {
        for b in IsaBody::ALL {
            assert_eq!(IsaBody::from_tag(b.tag()), Ok(b));
        }
        assert!(IsaBody::from_tag("sse9").is_err());
        assert!(IsaBody::from_tag("AVX2").is_err(),
                "tags are case-sensitive like the rest of the \
                 config grammar");
        assert!(IsaBody::from_tag("").is_err());
    }

    #[test]
    fn portable_is_always_available_and_last() {
        assert!(host_has(IsaBody::Portable));
        let avail = available_bodies();
        assert_eq!(*avail.last().expect("nonempty"),
                   IsaBody::Portable);
        // Every listed body must actually be runnable, and the
        // preferred body is the head of the list.
        for b in &avail {
            assert!(host_has(*b), "{} listed but unavailable",
                    b.tag());
        }
        assert_eq!(preferred(), avail[0]);
    }

    #[test]
    fn detection_is_consistent_with_arch() {
        // A body from a foreign architecture can never be detected.
        #[cfg(target_arch = "x86_64")]
        assert!(!host_has(IsaBody::Neon));
        #[cfg(target_arch = "aarch64")]
        {
            assert!(host_has(IsaBody::Neon));
            assert!(!host_has(IsaBody::Avx2));
            assert!(!host_has(IsaBody::Avx512));
        }
    }
}
