//! Persistent worker pool for the planar kernel's row-block tiling.
//!
//! ## Why a pool
//!
//! PR 1's kernel fanned output rows across `std::thread::scope`
//! threads, which spawns (and joins) an OS thread per block **per
//! GEMM**. A 256-cubed GEMM amortizes that fine; the serving hot path
//! does not — a coordinator shard issuing thousands of mid-size layer
//! GEMMs per second pays the spawn cost on every one of them, exactly
//! the dataflow-saturation failure mode PDPU (Li et al., 2023) warns
//! about: the posit datapath only wins when operands keep arriving.
//! This module replaces per-call spawns with **long-lived workers fed
//! by a channel work queue**: threads are created once (first use,
//! [`global`]), then every GEMM — from any thread, including
//! concurrent coordinator shards — enqueues row-block jobs and blocks
//! until its own jobs drain.
//!
//! ## Threading model
//!
//! * One process-wide pool ([`global`]), sized to the machine's
//!   available parallelism (`SPADE_KERNEL_THREADS`, when set at first
//!   use, overrides absolutely — the same knob, same semantics, as the
//!   per-GEMM fan-out). Workers block on an `mpsc` queue behind a mutex —
//!   contention is negligible because jobs are whole row blocks, not
//!   individual MACs.
//! * [`WorkerPool::run_scoped`] executes a set of **borrowing** jobs:
//!   the final job runs on the calling thread (the caller contributes
//!   instead of idling), the rest go to the queue. The call returns
//!   only after every job has finished — enforced by a countdown latch
//!   whose decrement sits in a `Drop` guard, so even a panicking job
//!   counts down and the scope never returns while a worker can still
//!   touch the caller's borrows. That completion guarantee is what
//!   makes the internal lifetime erasure sound (same contract as
//!   `std::thread::scope`, amortized).
//! * Worker panics are caught per job and re-raised on the calling
//!   thread after the scope completes; the workers themselves survive,
//!   so one poisoned GEMM cannot shrink the pool. As a second line of
//!   defense, every worker carries a respawn guard: if a panic ever
//!   *does* unwind a worker (a job that escaped the per-job catch),
//!   the dying thread spawns its own replacement on the same queue
//!   and the restart is counted
//!   ([`WorkerPool::workers_respawned`]) — the pool's capacity
//!   self-heals instead of silently shrinking.
//! * Dispatch is **not re-entrant**: pool jobs must not call
//!   [`WorkerPool::run_scoped`] themselves (deadlock hazard; debug
//!   builds assert). The kernel's jobs are leaf row-block computations,
//!   so the constraint is free today.
//!
//! ## Work stealing
//!
//! The pool supplies *threads*; [`RowQueue`] supplies *scheduling*.
//! GEMM jobs no longer receive fixed row blocks — each job loops
//! [`RowQueue::claim`] over a shared chunked cursor, so uneven chunks
//! (NaR-poisoned dense rows, a descheduled core) are absorbed by
//! whichever workers are still hungry instead of stalling a fixed
//! split.
//!
//! [`super::gemm::gemm_with_threads`] is the main client; benches
//! compare it against the retained fixed-split scope-spawning baseline
//! ([`super::gemm::gemm_with_scope`]) to track both spawn amortization
//! and straggler absorption (`steal_vs_fixed_split`).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};

/// Chunked atomic work queue over output rows — the work-stealing
/// half of the kernel's dispatch (the pool supplies the long-lived
/// threads, the queue decides who computes what).
///
/// [`super::gemm::gemm_with_threads`] used to hand each worker one
/// fixed contiguous row block; a straggler block (denser rows, a
/// descheduled worker) then gated the whole GEMM. Instead the rows are
/// carved into chunks of `chunk_rows` and every job loops
/// [`RowQueue::claim`] until the queue runs dry, so a fast worker
/// *steals* the chunks a slow one never got to — no idle lanes while
/// work remains (the retained fixed-split path,
/// [`super::gemm::gemm_with_scope`], is the bench baseline for exactly
/// this gap: `steal_vs_fixed_split`).
///
/// Each chunk is handed out **at most once** (a single
/// `fetch_add`-based cursor), which is what lets claimants safely
/// derive disjoint `&mut` output windows. `Relaxed` ordering suffices:
/// the counter only distributes indices, and completed writes are
/// published by the pool's scope-end latch, not by the queue.
pub struct RowQueue {
    rows: usize,
    chunk_rows: usize,
    next: AtomicUsize,
}

impl RowQueue {
    /// Queue over `rows` output rows in chunks of `chunk_rows` (≥ 1).
    pub fn new(rows: usize, chunk_rows: usize) -> RowQueue {
        assert!(chunk_rows >= 1, "chunk_rows must be at least 1");
        RowQueue { rows, chunk_rows, next: AtomicUsize::new(0) }
    }

    /// Total chunks this queue will hand out.
    pub fn chunks(&self) -> usize {
        self.rows.div_ceil(self.chunk_rows)
    }

    /// Rows per chunk (the last chunk may be shorter).
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// Claim the next chunk: a half-open row range `[r0, r1)`, or
    /// `None` when the queue is dry. Every row is covered by exactly
    /// one claim across all callers.
    pub fn claim(&self) -> Option<(usize, usize)> {
        let c = self.next.fetch_add(1, Ordering::Relaxed);
        match c.checked_mul(self.chunk_rows) {
            Some(r0) if r0 < self.rows => {
                Some((r0, (r0 + self.chunk_rows).min(self.rows)))
            }
            _ => None,
        }
    }

    /// Chunks successfully claimed so far (== [`RowQueue::chunks`]
    /// once the queue has drained).
    pub fn claimed(&self) -> usize {
        self.next.load(Ordering::Relaxed).min(self.chunks())
    }
}

/// A lifetime-erased unit of work (see [`WorkerPool::run_scoped`] for
/// why erasure is sound here).
type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// True on pool worker threads — lets [`WorkerPool::run_scoped`]
    /// catch re-entrant dispatch (a deadlock hazard) in debug builds.
    static IS_POOL_WORKER: std::cell::Cell<bool> =
        const { std::cell::Cell::new(false) };
}

/// Countdown latch: `wait` blocks until `count_down` has been called
/// once per outstanding job.
struct Latch {
    remaining: Mutex<usize>,
    all_done: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { remaining: Mutex::new(n), all_done: Condvar::new() }
    }

    fn count_down(&self) {
        let mut r = lock_recover(&self.remaining);
        *r -= 1;
        if *r == 0 {
            self.all_done.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = lock_recover(&self.remaining);
        while *r > 0 {
            r = match self.all_done.wait(r) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }
}

/// Lock a pool mutex, recovering from poison: the data under every
/// pool lock (a counter, a channel endpoint) is valid after any
/// interrupted critical section, and a panicking worker must not be
/// able to wedge every future GEMM by poisoning the queue.
fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Persistent pool of kernel worker threads. See module docs; most
/// callers want [`global`] rather than a private pool.
pub struct WorkerPool {
    /// Job queue entry point. `mpsc::Sender` predates `Sync` on older
    /// toolchains, so it lives behind a mutex and is cloned per scope.
    tx: Mutex<mpsc::Sender<Job>>,
    workers: usize,
    jobs_executed: Arc<AtomicU64>,
    respawned: Arc<AtomicU64>,
}

impl WorkerPool {
    /// Spawn a pool with `workers` long-lived threads (min 1). The
    /// threads are detached: they park on the empty queue and die with
    /// the process (or when the pool is dropped and the channel
    /// closes). Panics only if not a single worker could be spawned —
    /// a zero-worker pool would hang the first scope on its latch.
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let jobs_executed = Arc::new(AtomicU64::new(0));
        let respawned = Arc::new(AtomicU64::new(0));
        let mut spawned = 0usize;
        for i in 0..workers {
            if spawn_worker(i, rx.clone(), respawned.clone()).is_ok() {
                spawned += 1;
            }
        }
        if spawned == 0 {
            // lint: allow(no-unwrap): construction-time fail-fast.
            // No request has been accepted yet, and a pool with zero
            // workers could only deadlock every later submit.
            panic!("kernel pool: could not spawn any worker thread");
        }
        WorkerPool { tx: Mutex::new(tx), workers: spawned,
                     jobs_executed, respawned }
    }

    /// Number of worker threads. The count is fixed at construction:
    /// a worker that dies to an escaped panic is replaced in place by
    /// its respawn guard (see [`WorkerPool::workers_respawned`]), so
    /// capacity never shrinks.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// How many times a panicked worker has been replaced (0 in
    /// healthy operation: per-job panic capture means ordinary job
    /// panics never unwind a worker).
    pub fn workers_respawned(&self) -> u64 {
        self.respawned.load(Ordering::Acquire)
    }

    /// Total jobs executed **on pool workers** since construction
    /// (the per-scope job run inline on the caller is not counted).
    /// Monotonic; used by tests to prove GEMMs reuse the pool.
    pub fn jobs_executed(&self) -> u64 {
        self.jobs_executed.load(Ordering::Acquire)
    }

    /// Run a set of jobs that may borrow from the caller's stack,
    /// blocking until all of them complete.
    ///
    /// The last job runs inline on the calling thread; the rest are
    /// queued to the workers. If any job panics, the panic is
    /// re-raised here — but only after **every** job has finished, so
    /// borrowed data is never touched after the call returns (the
    /// `std::thread::scope` guarantee, without the per-call spawns).
    ///
    /// # Deadlock
    ///
    /// Not re-entrant: a pool **job** must not call `run_scoped` —
    /// the worker would block waiting for sub-jobs that can only run
    /// on (possibly all-blocked) workers. Debug builds assert; submit
    /// nested work from the owning thread instead. (Zero- and
    /// one-job scopes never touch the queue and are always safe.)
    pub fn run_scoped<'scope>(
        &self, mut jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>,
    ) {
        let Some(local) = jobs.pop() else {
            return;
        };
        if jobs.is_empty() {
            local();
            return;
        }
        debug_assert!(
            !IS_POOL_WORKER.with(|f| f.get()),
            "WorkerPool::run_scoped called from a pool worker — \
             re-entrant dispatch can deadlock the pool"
        );
        let latch = Arc::new(Latch::new(jobs.len()));
        let panicked = Arc::new(AtomicBool::new(false));
        {
            let tx = lock_recover(&self.tx).clone();
            for job in jobs {
                // SAFETY: the job may borrow data that only lives for
                // 'scope. Erasing that lifetime is sound because this
                // function does not return until `latch.wait()` has
                // observed every queued job's completion, and the
                // latch decrement lives in a Drop guard inside the
                // wrapper — it fires even if the job panics. No
                // worker can hold the borrow past this call.
                let job: Job = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'scope>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(job)
                };
                let latch = latch.clone();
                let panicked = panicked.clone();
                let counter = self.jobs_executed.clone();
                let wrapped: Job = Box::new(move || {
                    struct Done(Arc<Latch>);
                    impl Drop for Done {
                        fn drop(&mut self) {
                            self.0.count_down();
                        }
                    }
                    let _done = Done(latch);
                    if catch_unwind(AssertUnwindSafe(job)).is_err() {
                        panicked.store(true, Ordering::Release);
                    }
                    counter.fetch_add(1, Ordering::Release);
                });
                if let Err(mpsc::SendError(wrapped)) =
                    tx.send(wrapped)
                {
                    // Queue closed (every worker and the pool's own
                    // sender gone — cannot happen while the pool is
                    // alive, but must not lose work if it does): run
                    // the job inline so the latch still counts down.
                    wrapped();
                }
            }
        }
        // The caller works instead of idling; its panic (if any) is
        // deferred until the queued jobs are out of the borrow.
        let local_result = catch_unwind(AssertUnwindSafe(local));
        latch.wait();
        if let Err(payload) = local_result {
            resume_unwind(payload);
        }
        if panicked.load(Ordering::Acquire) {
            // lint: allow(no-unwrap): re-raises a worker job panic.
            // It surfaces on the submitting thread; the shard
            // supervisor's catch_unwind turns it into a restart +
            // re-queue, so the request still gets a typed reply.
            panic!("kernel pool job panicked (see worker backtrace)");
        }
    }
}

/// Spawn one pool worker on the shared queue. Each worker carries a
/// [`RespawnGuard`] so an escaped panic replaces the thread instead
/// of shrinking the pool.
fn spawn_worker(idx: usize, rx: Arc<Mutex<mpsc::Receiver<Job>>>,
                respawned: Arc<AtomicU64>) -> std::io::Result<()> {
    std::thread::Builder::new()
        .name(format!("spade-pool-{idx}"))
        .spawn(move || {
            let _guard = RespawnGuard { idx, rx: rx.clone(),
                                        respawned };
            worker_loop(rx);
        })
        .map(|_| ())
}

/// Armed on every worker: if the thread unwinds (a job escaped the
/// per-job `catch_unwind` — should never happen, but "should never"
/// is what supervision is for), `Drop` runs during the unwind, counts
/// the loss and spawns a replacement on the same queue. On a clean
/// exit (channel closed) `std::thread::panicking()` is false and the
/// guard does nothing.
struct RespawnGuard {
    idx: usize,
    rx: Arc<Mutex<mpsc::Receiver<Job>>>,
    respawned: Arc<AtomicU64>,
}

impl Drop for RespawnGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.respawned.fetch_add(1, Ordering::AcqRel);
            let _ = spawn_worker(self.idx, self.rx.clone(),
                                 self.respawned.clone());
        }
    }
}

/// Worker body: pull jobs until the channel closes. Jobs arrive
/// pre-wrapped with panic capture, so workers never unwind (the
/// respawn guard covers the day one does anyway).
fn worker_loop(rx: Arc<Mutex<mpsc::Receiver<Job>>>) {
    IS_POOL_WORKER.with(|f| f.set(true));
    loop {
        // Hold the queue lock only while dequeuing, never while
        // executing.
        let job = { lock_recover(&rx).recv() };
        match job {
            Ok(job) => job(),
            Err(_) => return,
        }
    }
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide kernel pool, created on first use. Sized to
/// `available_parallelism` unless the installed
/// [`super::settings::KernelConfig::pool_workers`] overrides
/// absolutely (it may deliberately oversubscribe, exactly as the
/// explicit thread knob lets [`super::gemm::auto_threads`] exceed the
/// core count for a per-GEMM fan-out). The size is latched here, at
/// first use: installing a new config later cannot resize a live
/// pool — build the engine before the first GEMM.
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| {
        let hw = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        let size = match super::settings::current().pool_workers {
            Some(v) if v >= 1 => v,
            _ => hw,
        };
        WorkerPool::new(size)
    })
}

/// The global pool **if it has already been created** — never
/// constructs it. Observers (the `--stats-json` dump) use this so
/// reporting pool counters cannot itself spawn a fleet of idle
/// workers on a serve that never touched the planar kernel.
pub fn try_global() -> Option<&'static WorkerPool> {
    GLOBAL.get()
}

#[cfg(test)]
impl WorkerPool {
    /// Push a **raw** job — no per-job panic capture, no latch — onto
    /// the queue, simulating the impossible: a panic that escapes the
    /// wrapper and unwinds a worker. Only the respawn-guard tests
    /// (here and the stats-dump counter-delta test in `api::engine`)
    /// use this; production jobs always go through `run_scoped`'s
    /// wrapper.
    pub(crate) fn inject_unwinding_job(&self) {
        let _ = lock_recover(&self.tx)
            .clone()
            .send(Box::new(|| panic!("injected raw worker panic")));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_jobs_write_disjoint_borrows() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0u64; 64];
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for (i, chunk) in data.chunks_mut(8).enumerate() {
            jobs.push(Box::new(move || {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (i * 8 + j) as u64;
                }
            }));
        }
        pool.run_scoped(jobs);
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
        // 8 jobs, 1 ran inline on this thread.
        assert_eq!(pool.jobs_executed(), 7);
        assert_eq!(pool.workers(), 3);
    }

    #[test]
    fn empty_and_single_job_scopes() {
        let pool = WorkerPool::new(2);
        pool.run_scoped(Vec::new()); // no-op
        let mut hit = false;
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        jobs.push(Box::new(|| hit = true));
        pool.run_scoped(jobs);
        assert!(hit);
        // single jobs run inline: no pool traffic at all
        assert_eq!(pool.jobs_executed(), 0);
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut jobs: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
            jobs.push(Box::new(|| panic!("boom")));
            jobs.push(Box::new(|| {}));
            pool.run_scoped(jobs);
        }));
        assert!(caught.is_err());
        // The worker that caught the panic is still serving.
        let mut ok = [false; 4];
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for slot in ok.iter_mut() {
            jobs.push(Box::new(move || *slot = true));
        }
        pool.run_scoped(jobs);
        assert!(ok.iter().all(|&b| b));
    }

    #[test]
    fn workers_are_long_lived_across_scopes() {
        use std::collections::HashSet;
        use std::thread::ThreadId;
        let pool = WorkerPool::new(2);
        let ids: Mutex<HashSet<ThreadId>> = Mutex::new(HashSet::new());
        let caller = std::thread::current().id();
        for _ in 0..8 {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::new();
            for _ in 0..4 {
                jobs.push(Box::new(|| {
                    ids.lock()
                        .unwrap()
                        .insert(std::thread::current().id());
                }));
            }
            pool.run_scoped(jobs);
        }
        // 24 queued jobs across 8 scopes all landed on the same two
        // long-lived workers (plus the caller running each scope's
        // local job). Per-call spawning would mint fresh ThreadIds on
        // every scope and blow past the worker count.
        let ids = ids.into_inner().unwrap();
        let workers: HashSet<ThreadId> = ids
            .iter()
            .copied()
            .filter(|id| *id != caller)
            .collect();
        assert!(!workers.is_empty());
        assert!(workers.len() <= 2,
                "{} distinct worker threads for a 2-worker pool",
                workers.len());
    }

    #[test]
    fn row_queue_covers_rows_exactly_once() {
        let q = RowQueue::new(23, 4);
        assert_eq!(q.chunks(), 6);
        assert_eq!(q.chunk_rows(), 4);
        let mut seen = vec![false; 23];
        while let Some((r0, r1)) = q.claim() {
            assert!(r1 > r0 && r1 <= 23);
            for r in r0..r1 {
                assert!(!seen[r], "row {r} claimed twice");
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "rows left unclaimed");
        assert_eq!(q.claimed(), 6);
        assert!(q.claim().is_none(), "dry queue must stay dry");
        assert_eq!(q.claimed(), 6);
    }

    #[test]
    fn row_queue_empty_and_oversized_chunks() {
        let q = RowQueue::new(0, 3);
        assert_eq!(q.chunks(), 0);
        assert!(q.claim().is_none());
        let q = RowQueue::new(2, 100); // chunk bigger than the matrix
        assert_eq!(q.chunks(), 1);
        assert_eq!(q.claim(), Some((0, 2)));
        assert!(q.claim().is_none());
    }

    #[test]
    fn row_queue_concurrent_claims_are_disjoint() {
        // Drive the queue through the pool itself: stealing jobs must
        // cover every row exactly once, with claim counts summing to
        // the chunk total no matter how the race lands.
        let pool = WorkerPool::new(3);
        let q = RowQueue::new(101, 3);
        let hits: Vec<AtomicUsize> =
            (0..101).map(|_| AtomicUsize::new(0)).collect();
        let claims: Vec<AtomicUsize> =
            (0..4).map(|_| AtomicUsize::new(0)).collect();
        {
            let (q, hits, claims) = (&q, &hits, &claims);
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::new();
            for ti in 0..4 {
                jobs.push(Box::new(move || {
                    while let Some((r0, r1)) = q.claim() {
                        claims[ti].fetch_add(1, Ordering::Relaxed);
                        for r in r0..r1 {
                            hits[r].fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }));
            }
            pool.run_scoped(jobs);
        }
        assert!(hits
            .iter()
            .all(|h| h.load(Ordering::Relaxed) == 1));
        let total: usize = claims
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum();
        assert_eq!(total, q.chunks());
        assert_eq!(q.claimed(), q.chunks());
    }

    #[test]
    fn panicked_worker_is_respawned() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.workers_respawned(), 0);
        pool.inject_unwinding_job();
        // The guard fires during the victim's unwind; give it a
        // bounded spin to land.
        let deadline = std::time::Instant::now()
            + std::time::Duration::from_secs(5);
        while pool.workers_respawned() < 1 {
            assert!(std::time::Instant::now() < deadline,
                    "worker was never respawned");
            std::thread::yield_now();
        }
        assert_eq!(pool.workers_respawned(), 1);
        // The replacement serves the same queue: a full scope still
        // completes with the pool back at capacity.
        let mut ok = [false; 8];
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for slot in ok.iter_mut() {
            jobs.push(Box::new(move || *slot = true));
        }
        pool.run_scoped(jobs);
        assert!(ok.iter().all(|&b| b));
        assert_eq!(pool.workers_respawned(), 1,
                   "healthy jobs must not trigger further respawns");
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = global() as *const WorkerPool;
        let b = global() as *const WorkerPool;
        assert_eq!(a, b);
        assert!(global().workers() >= 1);
    }
}
