//! Process-wide kernel configuration — explicit, typed, **no
//! environment reads** — plus the autotuner's tuned-winner table.
//!
//! Since PR 4 the kernel never consults `std::env` itself: every knob
//! that used to be an ad-hoc `SPADE_KERNEL_*` read (worker counts,
//! tile parameters, the gather path) lives in a [`KernelConfig`] that
//! callers thread through explicitly ([`super::gemm::gemm_with_config`],
//! [`crate::nn::exec::Session::set_kernel_config`],
//! [`crate::coordinator::CoordinatorConfig::kernel`]). Environment
//! variables are parsed **once**, at the process edge, by
//! [`crate::api::EngineConfig::from_env`] (the only module allowed to
//! read `SPADE_*` — `scripts/verify.sh` greps for violations), and
//! [`crate::api::EngineBuilder::build`] installs the result here as
//! the process default.
//!
//! The default is what the convenience entry points
//! ([`super::gemm::gemm`], [`super::gemm::gemm_with_threads`],
//! [`crate::systolic::gemm::SystolicGemm::run`]) use when no explicit
//! config is handed to them. Changing it never changes *results* —
//! every tile/thread/path combination is bit-identical by construction
//! (exact integer accumulation, one rounding) — only how fast they
//! arrive. The same holds for the fused epilogue
//! ([`super::gemm::gemm_fused_into`]): it is orthogonal to tile
//! geometry and threading, riding whatever row chunks dispatch (and
//! the autotuner's shape classes) pick, so a config tuned on the word
//! GEMM resolves identically for fused calls.
//!
//! ## The tuned-winner table
//!
//! [`super::autotune`] caches one winning (tile, path) per
//! (precision-nbits, [`ShapeClass`]) here, process-wide: shards,
//! sessions and direct kernel callers all share the probes one of
//! them paid. The table only ever *re-tunes* dispatch — winners are
//! bit-identical by construction — so concurrent install/lookup needs
//! no coordination beyond the `RwLock`.

use std::collections::BTreeMap;
use std::sync::RwLock;

use super::autotune::{AutotuneMode, ShapeClass, Tuned};
use super::isa::{self, IsaBody};
use super::simd::{InnerPath, TileConfig};
use crate::util::Json;

/// Explicit kernel configuration: everything the GEMM dispatch and
/// inner loops need to know, in one copyable value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelConfig {
    /// Per-GEMM worker count override. `None` = the automatic
    /// heuristic ([`super::gemm::auto_threads`]); `Some(n)` is
    /// absolute (clamped only to the row count, so it may deliberately
    /// oversubscribe).
    pub threads: Option<usize>,
    /// Persistent worker-pool size ([`super::pool::global`]). `None` =
    /// the machine's available parallelism. Read **once**, at first
    /// pool use — installing a new default later cannot resize a pool
    /// that already exists.
    pub pool_workers: Option<usize>,
    /// Tile/panel/steal-chunk/k-chunk geometry. `None` = untuned: the
    /// built-in [`TileConfig::DEFAULT`], or the autotuned winner for
    /// the GEMM's (precision, shape class) when
    /// [`KernelConfig::autotune`] enables it. `Some` is an **explicit
    /// pin and always wins** — the autotuner never overrides a tile
    /// the caller chose.
    pub tile: Option<TileConfig>,
    /// Inner-loop body `gemm` routes through. [`InnerPath::Auto`]
    /// (the default) upgrades P8 to the AVX2 gather when the CPU has
    /// it and accepts autotuned path winners;
    /// [`InnerPath::Portable`] pins the portable lane loops (the
    /// old `SPADE_KERNEL_GATHER=0` behavior) and, like every
    /// non-`Auto` value, overrides a tuned path.
    pub path: InnerPath,
    /// When the first-use autotuner may probe
    /// ([`super::autotune::AutotuneMode`]; default `Off`).
    pub autotune: AutotuneMode,
    /// ISA-body pin ([`super::isa::IsaBody`]). `None` (= `auto`, the
    /// default) lets dispatch pick: the tuned winner when one exists,
    /// otherwise the best body the host detects. `Some` is an
    /// explicit pin — validated against the host at the config edge
    /// ([`crate::api::EngineConfig::validate`]) and honored by every
    /// P8 dispatch (including autotune probes, which pin the body
    /// they are timing).
    pub isa: Option<IsaBody>,
}

impl KernelConfig {
    /// The built-in default: auto threads, auto pool, untuned default
    /// tiles, auto inner path, autotuner off.
    pub const DEFAULT: KernelConfig = KernelConfig {
        threads: None,
        pool_workers: None,
        tile: None,
        path: InnerPath::Auto,
        autotune: AutotuneMode::Off,
        isa: None,
    };

    /// The tile geometry this config pins, or the built-in defaults —
    /// **without** consulting the autotuner (dispatch resolution goes
    /// through `autotune::resolve`, which also folds in tuned
    /// winners).
    pub fn tile_or_default(&self) -> TileConfig {
        self.tile.unwrap_or(TileConfig::DEFAULT)
    }
}

impl Default for KernelConfig {
    fn default() -> KernelConfig {
        KernelConfig::DEFAULT
    }
}

static CURRENT: RwLock<KernelConfig> = RwLock::new(KernelConfig::DEFAULT);

/// The process-wide default [`KernelConfig`] — what the implicit
/// kernel entry points use. Cheap (one uncontended read lock per
/// GEMM-level call, never per MAC).
pub fn current() -> KernelConfig {
    *CURRENT.read().unwrap()
}

/// Install `cfg` as the process-wide default. Called by
/// [`crate::api::EngineBuilder::build`]; tests may call it directly.
/// Results are bit-identical under any config, so a concurrent
/// install can never corrupt an in-flight GEMM — it only retunes
/// later ones. Note the pool-size caveat on
/// [`KernelConfig::pool_workers`].
pub fn install(cfg: KernelConfig) {
    *CURRENT.write().unwrap() = cfg;
}

/// Autotuned winners per (precision nbits, shape class) — the
/// process-wide cache [`super::autotune`] fills and
/// `autotune::resolve` reads on every untuned dispatch.
static TUNED: RwLock<BTreeMap<(u32, ShapeClass), Tuned>> =
    RwLock::new(BTreeMap::new());

/// Look up the cached autotune winner for a tuning key.
pub fn tuned_lookup(key: (u32, ShapeClass)) -> Option<Tuned> {
    TUNED.read().unwrap().get(&key).copied()
}

/// Install an autotune winner (last write wins — winners are
/// bit-identical by construction, so a race costs nothing but a
/// redundant probe).
pub fn tuned_install(key: (u32, ShapeClass), t: Tuned) {
    TUNED.write().unwrap().insert(key, t);
}

/// Number of (precision, shape class) pairs tuned so far.
pub fn tuned_count() -> usize {
    TUNED.read().unwrap().len()
}

/// Drop every cached winner (tests; a process serving real traffic
/// has no reason to forget its probes).
pub fn tuned_clear() {
    TUNED.write().unwrap().clear();
}

/// Snapshot of the whole tuned table, key-sorted (the `BTreeMap`
/// order), so serialization is deterministic.
pub fn tuned_snapshot() -> Vec<((u32, ShapeClass), Tuned)> {
    TUNED.read().unwrap().iter().map(|(k, v)| (*k, *v)).collect()
}

/// `k_chunk` can legitimately be `usize::MAX` (the autotuner's
/// "never chunk" candidate). JSON numbers are f64 and cannot hold
/// that exactly, so the schema spells it `"max"`.
fn k_chunk_json(v: usize) -> String {
    if v == usize::MAX {
        "\"max\"".to_string()
    } else {
        v.to_string()
    }
}

fn k_chunk_from_json(j: &Json) -> Result<usize, String> {
    if let Some(s) = j.as_str() {
        return if s == "max" {
            Ok(usize::MAX)
        } else {
            Err(format!("\"k_chunk\": unknown string {s:?} \
                         (expected a count or \"max\")"))
        };
    }
    j.as_usize()
        .ok_or_else(|| "\"k_chunk\": expected a count or \"max\""
            .to_string())
}

/// Render the tuned table as `spade-tuned-v1` JSON — the sidecar
/// `Engine::warm_up` persists next to the `EngineConfig` JSON so a
/// fleet of identical machines probes once, not per process.
///
/// One entry per (nbits, shape class) key; tile fields are flattened,
/// `path`/`body`/`class` use the same string grammar as the config
/// layer. Deterministic (key-sorted) output.
pub fn tuned_to_json() -> String {
    let snap = tuned_snapshot();
    let mut s = String::new();
    s.push_str("{\n  \"schema\": \"spade-tuned-v1\",\n");
    s.push_str("  \"entries\": [");
    for (i, ((nbits, class), t)) in snap.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n    {{\"nbits\": {nbits}, \"class\": \"{}\", \
             \"p16_panel\": {}, \"p32_panel\": {}, \
             \"steal_rows\": {}, \"k_chunk\": {}, \
             \"path\": \"{}\", \"body\": \"{}\"}}",
            class.tag_string(),
            t.tile.p16_panel,
            t.tile.p32_panel,
            t.tile.steal_rows,
            k_chunk_json(t.tile.k_chunk),
            t.path.tag(),
            t.body.tag()));
    }
    if !snap.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("]\n}\n");
    s
}

/// Parse `spade-tuned-v1` JSON and install its entries into the
/// process-wide tuned table. **Strict**: a wrong schema tag, unknown
/// or missing keys, bad types, or an unknown `class`/`path`/`body`
/// tag is a hard error — a corrupt sidecar must fail loudly, not
/// half-tune a fleet. The one *soft* case is an entry whose `body`
/// the loading host cannot run (the file came from a different
/// machine): that entry is **skipped** — the shape class re-probes
/// here — and the skip count is returned alongside the install count.
pub fn tuned_merge_json(src: &str)
                        -> Result<(usize, usize), String> {
    let root = Json::parse(src)?;
    let obj = root.as_obj()
        .ok_or("tuned table: top level must be an object")?;
    match root.get("schema").and_then(Json::as_str) {
        Some("spade-tuned-v1") => {}
        Some(other) => {
            return Err(format!(
                "tuned table: schema {other:?} (expected \
                 \"spade-tuned-v1\")"));
        }
        None => {
            return Err("tuned table: missing \"schema\"".to_string());
        }
    }
    for key in obj.keys() {
        if key != "schema" && key != "entries" {
            return Err(format!("tuned table: unknown key {key:?}"));
        }
    }
    let entries = root.get("entries").and_then(Json::as_arr)
        .ok_or("tuned table: \"entries\" must be an array")?;

    const ENTRY_KEYS: &[&str] =
        &["nbits", "class", "p16_panel", "p32_panel", "steal_rows",
          "k_chunk", "path", "body"];
    let mut parsed: Vec<((u32, ShapeClass), Tuned)> = Vec::new();
    let mut skipped = 0usize;
    for (i, e) in entries.iter().enumerate() {
        let eobj = e.as_obj().ok_or_else(|| {
            format!("tuned table: entry {i} must be an object")
        })?;
        for key in eobj.keys() {
            if !ENTRY_KEYS.contains(&key.as_str()) {
                return Err(format!(
                    "tuned table: entry {i}: unknown key {key:?}"));
            }
        }
        let field = |name: &str| {
            e.get(name).ok_or_else(|| {
                format!("tuned table: entry {i}: missing {name:?}")
            })
        };
        let count = |name: &str| -> Result<usize, String> {
            field(name)?.as_usize().ok_or_else(|| {
                format!("tuned table: entry {i}: {name:?} must be a \
                         non-negative count")
            })
        };
        let tag = |name: &str| -> Result<&str, String> {
            field(name)?.as_str().ok_or_else(|| {
                format!("tuned table: entry {i}: {name:?} must be a \
                         string")
            })
        };
        let nbits = count("nbits")?;
        if nbits == 0 || nbits > 64 {
            return Err(format!(
                "tuned table: entry {i}: \"nbits\" {nbits} out of \
                 range"));
        }
        let class = ShapeClass::from_tag(tag("class")?)
            .map_err(|e| format!("tuned table: entry {i}: {e}"))?;
        let tile = TileConfig {
            p16_panel: count("p16_panel")?,
            p32_panel: count("p32_panel")?,
            steal_rows: count("steal_rows")?,
            k_chunk: k_chunk_from_json(field("k_chunk")?)
                .map_err(|e| format!("tuned table: entry {i}: {e}"))?,
        };
        if tile.p16_panel == 0 || tile.p32_panel == 0 {
            return Err(format!(
                "tuned table: entry {i}: zero panel width"));
        }
        let path = InnerPath::from_tag(tag("path")?)
            .map_err(|e| format!("tuned table: entry {i}: {e}"))?;
        let body = IsaBody::from_tag(tag("body")?)
            .map_err(|e| format!("tuned table: entry {i}: {e}"))?;
        if !isa::host_has(body) {
            // Tuned on a different host; its winner is meaningless
            // (and possibly unrunnable) here. Skip → re-probe.
            skipped += 1;
            continue;
        }
        parsed.push(((nbits as u32, class),
                     Tuned { tile, path, body }));
    }
    // Strictness first, installation second: nothing lands unless the
    // whole file parsed.
    let installed = parsed.len();
    for (key, t) in parsed {
        tuned_install(key, t);
    }
    Ok((installed, skipped))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip() {
        assert_eq!(KernelConfig::default(), KernelConfig::DEFAULT);
        assert_eq!(KernelConfig::DEFAULT.tile, None);
        assert_eq!(KernelConfig::DEFAULT.tile_or_default(),
                   TileConfig::default());
        assert_eq!(KernelConfig::DEFAULT.path, InnerPath::Auto);
        assert_eq!(KernelConfig::DEFAULT.autotune, AutotuneMode::Off);
        // current() starts at the default (other tests may have
        // installed something by now; just exercise the accessors).
        let c = current();
        install(c);
        assert_eq!(current(), c);
    }

    #[test]
    fn tuned_table_roundtrip() {
        let key = (63u32, ShapeClass::Square); // no real format is 63b
        assert_eq!(tuned_lookup(key), None);
        let t = Tuned {
            tile: TileConfig { p16_panel: 16, ..TileConfig::DEFAULT },
            path: InnerPath::Portable,
            body: IsaBody::Portable,
        };
        tuned_install(key, t);
        assert_eq!(tuned_lookup(key), Some(t));
        assert!(tuned_count() >= 1);
    }

    #[test]
    fn tuned_json_merge_installs_and_skips_foreign_bodies() {
        // Distinct fake nbits keys so this test cannot collide with
        // real tuning done by concurrent tests.
        let src = r#"{
  "schema": "spade-tuned-v1",
  "entries": [
    {"nbits": 61, "class": "deep-k", "p16_panel": 64,
     "p32_panel": 32, "steal_rows": 0, "k_chunk": "max",
     "path": "portable", "body": "portable"},
    {"nbits": 61, "class": "sparse-10", "p16_panel": 64,
     "p32_panel": 32, "steal_rows": 4, "k_chunk": 0,
     "path": "auto", "body": "portable"}
  ]
}"#;
        let (installed, skipped) =
            tuned_merge_json(src).expect("valid v1 file");
        assert_eq!((installed, skipped), (2, 0));
        let t = tuned_lookup((61, ShapeClass::DeepK)).unwrap();
        assert_eq!(t.tile.k_chunk, usize::MAX);
        assert_eq!(t.path, InnerPath::Portable);
        assert_eq!(t.body, IsaBody::Portable);
        let s = tuned_lookup((61, ShapeClass::Sparse(10))).unwrap();
        assert_eq!(s.tile.steal_rows, 4);

        // An entry tuned for a body this host lacks is skipped, not
        // installed and not an error (different machine's sidecar).
        let foreign = IsaBody::ALL
            .into_iter()
            .find(|b| !super::isa::host_has(*b))
            .map(|b| b.tag());
        if let Some(tag) = foreign {
            let src = format!(
                r#"{{"schema": "spade-tuned-v1", "entries": [
    {{"nbits": 62, "class": "skinny", "p16_panel": 64,
     "p32_panel": 32, "steal_rows": 1, "k_chunk": 0,
     "path": "auto", "body": "{tag}"}}]}}"#);
            assert_eq!(tuned_merge_json(&src), Ok((0, 1)));
            assert_eq!(tuned_lookup((62, ShapeClass::Skinny)), None);
        }
    }

    #[test]
    fn tuned_json_is_strict_about_corruption() {
        for (bad, why) in [
            ("{}", "missing schema"),
            (r#"{"schema": "spade-tuned-v2", "entries": []}"#,
             "wrong schema"),
            (r#"{"schema": "spade-tuned-v1"}"#, "missing entries"),
            (r#"{"schema": "spade-tuned-v1", "entries": [], "x": 1}"#,
             "unknown top-level key"),
            (r#"{"schema": "spade-tuned-v1", "entries": [
                {"nbits": 8, "class": "square", "p16_panel": 64,
                 "p32_panel": 32, "steal_rows": 0, "k_chunk": 0,
                 "path": "auto"}]}"#,
             "missing body"),
            (r#"{"schema": "spade-tuned-v1", "entries": [
                {"nbits": 8, "class": "square", "p16_panel": 64,
                 "p32_panel": 32, "steal_rows": 0, "k_chunk": 0,
                 "path": "auto", "body": "mmx"}]}"#,
             "unknown body tag"),
            (r#"{"schema": "spade-tuned-v1", "entries": [
                {"nbits": 8, "class": "oblong", "p16_panel": 64,
                 "p32_panel": 32, "steal_rows": 0, "k_chunk": 0,
                 "path": "auto", "body": "portable"}]}"#,
             "unknown class tag"),
            (r#"{"schema": "spade-tuned-v1", "entries": [
                {"nbits": 8, "class": "square", "p16_panel": 0,
                 "p32_panel": 32, "steal_rows": 0, "k_chunk": 0,
                 "path": "auto", "body": "portable"}]}"#,
             "zero panel"),
            (r#"{"schema": "spade-tuned-v1", "entries": [
                {"nbits": 8, "class": "square", "p16_panel": 64,
                 "p32_panel": 32, "steal_rows": 0, "k_chunk": "lots",
                 "path": "auto", "body": "portable"}]}"#,
             "bad k_chunk string"),
            (r#"{"schema": "spade-tuned-v1", "entries": [
                {"nbits": 8, "class": "square", "p16_panel": 64,
                 "p32_panel": 32, "steal_rows": 0, "k_chunk": 0,
                 "path": "auto", "body": "portable",
                 "speed": "yes"}]}"#,
             "unknown entry key"),
            ("not json at all", "parse failure"),
        ] {
            assert!(tuned_merge_json(bad).is_err(),
                    "corrupt tuned table accepted: {why}");
        }
    }
}
