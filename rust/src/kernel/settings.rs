//! Process-wide kernel configuration — explicit, typed, **no
//! environment reads** — plus the autotuner's tuned-winner table.
//!
//! Since PR 4 the kernel never consults `std::env` itself: every knob
//! that used to be an ad-hoc `SPADE_KERNEL_*` read (worker counts,
//! tile parameters, the gather path) lives in a [`KernelConfig`] that
//! callers thread through explicitly ([`super::gemm::gemm_with_config`],
//! [`crate::nn::exec::Session::set_kernel_config`],
//! [`crate::coordinator::CoordinatorConfig::kernel`]). Environment
//! variables are parsed **once**, at the process edge, by
//! [`crate::api::EngineConfig::from_env`] (the only module allowed to
//! read `SPADE_*` — `scripts/verify.sh` greps for violations), and
//! [`crate::api::EngineBuilder::build`] installs the result here as
//! the process default.
//!
//! The default is what the convenience entry points
//! ([`super::gemm::gemm`], [`super::gemm::gemm_with_threads`],
//! [`crate::systolic::gemm::SystolicGemm::run`]) use when no explicit
//! config is handed to them. Changing it never changes *results* —
//! every tile/thread/path combination is bit-identical by construction
//! (exact integer accumulation, one rounding) — only how fast they
//! arrive. The same holds for the fused epilogue
//! ([`super::gemm::gemm_fused_into`]): it is orthogonal to tile
//! geometry and threading, riding whatever row chunks dispatch (and
//! the autotuner's shape classes) pick, so a config tuned on the word
//! GEMM resolves identically for fused calls.
//!
//! ## The tuned-winner table
//!
//! [`super::autotune`] caches one winning (tile, path) per
//! (precision-nbits, [`ShapeClass`]) here, process-wide: shards,
//! sessions and direct kernel callers all share the probes one of
//! them paid. The table only ever *re-tunes* dispatch — winners are
//! bit-identical by construction — so concurrent install/lookup needs
//! no coordination beyond the `RwLock`.

use std::collections::BTreeMap;
use std::sync::RwLock;

use super::autotune::{AutotuneMode, ShapeClass, Tuned};
use super::simd::{InnerPath, TileConfig};

/// Explicit kernel configuration: everything the GEMM dispatch and
/// inner loops need to know, in one copyable value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelConfig {
    /// Per-GEMM worker count override. `None` = the automatic
    /// heuristic ([`super::gemm::auto_threads`]); `Some(n)` is
    /// absolute (clamped only to the row count, so it may deliberately
    /// oversubscribe).
    pub threads: Option<usize>,
    /// Persistent worker-pool size ([`super::pool::global`]). `None` =
    /// the machine's available parallelism. Read **once**, at first
    /// pool use — installing a new default later cannot resize a pool
    /// that already exists.
    pub pool_workers: Option<usize>,
    /// Tile/panel/steal-chunk/k-chunk geometry. `None` = untuned: the
    /// built-in [`TileConfig::DEFAULT`], or the autotuned winner for
    /// the GEMM's (precision, shape class) when
    /// [`KernelConfig::autotune`] enables it. `Some` is an **explicit
    /// pin and always wins** — the autotuner never overrides a tile
    /// the caller chose.
    pub tile: Option<TileConfig>,
    /// Inner-loop body `gemm` routes through. [`InnerPath::Auto`]
    /// (the default) upgrades P8 to the AVX2 gather when the CPU has
    /// it and accepts autotuned path winners;
    /// [`InnerPath::Portable`] pins the portable lane loops (the
    /// old `SPADE_KERNEL_GATHER=0` behavior) and, like every
    /// non-`Auto` value, overrides a tuned path.
    pub path: InnerPath,
    /// When the first-use autotuner may probe
    /// ([`super::autotune::AutotuneMode`]; default `Off`).
    pub autotune: AutotuneMode,
}

impl KernelConfig {
    /// The built-in default: auto threads, auto pool, untuned default
    /// tiles, auto inner path, autotuner off.
    pub const DEFAULT: KernelConfig = KernelConfig {
        threads: None,
        pool_workers: None,
        tile: None,
        path: InnerPath::Auto,
        autotune: AutotuneMode::Off,
    };

    /// The tile geometry this config pins, or the built-in defaults —
    /// **without** consulting the autotuner (dispatch resolution goes
    /// through `autotune::resolve`, which also folds in tuned
    /// winners).
    pub fn tile_or_default(&self) -> TileConfig {
        self.tile.unwrap_or(TileConfig::DEFAULT)
    }
}

impl Default for KernelConfig {
    fn default() -> KernelConfig {
        KernelConfig::DEFAULT
    }
}

static CURRENT: RwLock<KernelConfig> = RwLock::new(KernelConfig::DEFAULT);

/// The process-wide default [`KernelConfig`] — what the implicit
/// kernel entry points use. Cheap (one uncontended read lock per
/// GEMM-level call, never per MAC).
pub fn current() -> KernelConfig {
    *CURRENT.read().unwrap()
}

/// Install `cfg` as the process-wide default. Called by
/// [`crate::api::EngineBuilder::build`]; tests may call it directly.
/// Results are bit-identical under any config, so a concurrent
/// install can never corrupt an in-flight GEMM — it only retunes
/// later ones. Note the pool-size caveat on
/// [`KernelConfig::pool_workers`].
pub fn install(cfg: KernelConfig) {
    *CURRENT.write().unwrap() = cfg;
}

/// Autotuned winners per (precision nbits, shape class) — the
/// process-wide cache [`super::autotune`] fills and
/// `autotune::resolve` reads on every untuned dispatch.
static TUNED: RwLock<BTreeMap<(u32, ShapeClass), Tuned>> =
    RwLock::new(BTreeMap::new());

/// Look up the cached autotune winner for a tuning key.
pub fn tuned_lookup(key: (u32, ShapeClass)) -> Option<Tuned> {
    TUNED.read().unwrap().get(&key).copied()
}

/// Install an autotune winner (last write wins — winners are
/// bit-identical by construction, so a race costs nothing but a
/// redundant probe).
pub fn tuned_install(key: (u32, ShapeClass), t: Tuned) {
    TUNED.write().unwrap().insert(key, t);
}

/// Number of (precision, shape class) pairs tuned so far.
pub fn tuned_count() -> usize {
    TUNED.read().unwrap().len()
}

/// Drop every cached winner (tests; a process serving real traffic
/// has no reason to forget its probes).
pub fn tuned_clear() {
    TUNED.write().unwrap().clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip() {
        assert_eq!(KernelConfig::default(), KernelConfig::DEFAULT);
        assert_eq!(KernelConfig::DEFAULT.tile, None);
        assert_eq!(KernelConfig::DEFAULT.tile_or_default(),
                   TileConfig::default());
        assert_eq!(KernelConfig::DEFAULT.path, InnerPath::Auto);
        assert_eq!(KernelConfig::DEFAULT.autotune, AutotuneMode::Off);
        // current() starts at the default (other tests may have
        // installed something by now; just exercise the accessors).
        let c = current();
        install(c);
        assert_eq!(current(), c);
    }

    #[test]
    fn tuned_table_roundtrip() {
        let key = (63u32, ShapeClass::Square); // no real format is 63b
        assert_eq!(tuned_lookup(key), None);
        let t = Tuned {
            tile: TileConfig { p16_panel: 16, ..TileConfig::DEFAULT },
            path: InnerPath::Portable,
        };
        tuned_install(key, t);
        assert_eq!(tuned_lookup(key), Some(t));
        assert!(tuned_count() >= 1);
    }
}
