//! Process-wide kernel configuration — explicit, typed, **no
//! environment reads**.
//!
//! Since PR 4 the kernel never consults `std::env` itself: every knob
//! that used to be an ad-hoc `SPADE_KERNEL_*` read (worker counts,
//! tile parameters, the gather path) lives in a [`KernelConfig`] that
//! callers thread through explicitly ([`super::gemm::gemm_with_config`],
//! [`crate::nn::exec::Session::set_kernel_config`],
//! [`crate::coordinator::CoordinatorConfig::kernel`]). Environment
//! variables are parsed **once**, at the process edge, by
//! [`crate::api::EngineConfig::from_env`] (the only module allowed to
//! read `SPADE_*` — `scripts/verify.sh` greps for violations), and
//! [`crate::api::EngineBuilder::build`] installs the result here as
//! the process default.
//!
//! The default is what the convenience entry points
//! ([`super::gemm::gemm`], [`super::gemm::gemm_with_threads`],
//! [`crate::systolic::gemm::SystolicGemm::run`]) use when no explicit
//! config is handed to them. Changing it never changes *results* —
//! every tile/thread/path combination is bit-identical by construction
//! (exact integer accumulation, one rounding) — only how fast they
//! arrive.

use std::sync::RwLock;

use super::simd::{InnerPath, TileConfig};

/// Explicit kernel configuration: everything the GEMM dispatch and
/// inner loops need to know, in one copyable value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelConfig {
    /// Per-GEMM worker count override. `None` = the automatic
    /// heuristic ([`super::gemm::auto_threads`]); `Some(n)` is
    /// absolute (clamped only to the row count, so it may deliberately
    /// oversubscribe).
    pub threads: Option<usize>,
    /// Persistent worker-pool size ([`super::pool::global`]). `None` =
    /// the machine's available parallelism. Read **once**, at first
    /// pool use — installing a new default later cannot resize a pool
    /// that already exists.
    pub pool_workers: Option<usize>,
    /// Tile/panel/steal-chunk geometry (see [`TileConfig`]).
    pub tile: TileConfig,
    /// Inner-loop body `gemm` routes through. [`InnerPath::Auto`]
    /// (the default) upgrades P8 to the AVX2 gather when the CPU has
    /// it; [`InnerPath::Portable`] pins the portable lane loops (the
    /// old `SPADE_KERNEL_GATHER=0` behavior).
    pub path: InnerPath,
}

impl KernelConfig {
    /// The built-in default: auto threads, auto pool, default tiles,
    /// auto inner path.
    pub const DEFAULT: KernelConfig = KernelConfig {
        threads: None,
        pool_workers: None,
        tile: TileConfig::DEFAULT,
        path: InnerPath::Auto,
    };
}

impl Default for KernelConfig {
    fn default() -> KernelConfig {
        KernelConfig::DEFAULT
    }
}

static CURRENT: RwLock<KernelConfig> = RwLock::new(KernelConfig::DEFAULT);

/// The process-wide default [`KernelConfig`] — what the implicit
/// kernel entry points use. Cheap (one uncontended read lock per
/// GEMM-level call, never per MAC).
pub fn current() -> KernelConfig {
    *CURRENT.read().unwrap()
}

/// Install `cfg` as the process-wide default. Called by
/// [`crate::api::EngineBuilder::build`]; tests may call it directly.
/// Results are bit-identical under any config, so a concurrent
/// install can never corrupt an in-flight GEMM — it only retunes
/// later ones. Note the pool-size caveat on
/// [`KernelConfig::pool_workers`].
pub fn install(cfg: KernelConfig) {
    *CURRENT.write().unwrap() = cfg;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip() {
        assert_eq!(KernelConfig::default(), KernelConfig::DEFAULT);
        assert_eq!(KernelConfig::DEFAULT.tile, TileConfig::default());
        assert_eq!(KernelConfig::DEFAULT.path, InnerPath::Auto);
        // current() starts at the default (other tests may have
        // installed something by now; just exercise the accessors).
        let c = current();
        install(c);
        assert_eq!(current(), c);
    }
}
