//! `spade-lint` — walk the tree and enforce the project invariants.
//!
//! ```text
//! cargo run --release --bin spade-lint [-- --root DIR] [--json PATH]
//! ```
//!
//! Prints findings as `file:line [rule] message`, writes
//! `LINT_report.json` (schema `spade-lint-v1`) at the repo root, and
//! exits nonzero when any unsuppressed finding remains. See
//! [`spade::lint`] for the rule catalog and suppression syntax.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut json: Option<PathBuf> =
        Some(PathBuf::from("LINT_report.json"));
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => {
                if let Some(v) = args.next() {
                    root = PathBuf::from(v);
                }
            }
            "--json" => {
                json = args.next().map(PathBuf::from);
            }
            "--no-json" => json = None,
            "--help" | "-h" => {
                eprintln!(
                    "usage: spade-lint [--root DIR] [--json PATH | \
                     --no-json]");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("spade-lint: unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
    }

    let report = match spade::lint::lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("spade-lint: walking {}: {e}",
                      root.display());
            return ExitCode::from(2);
        }
    };

    for f in &report.findings {
        println!("{f}");
    }
    if let Some(path) = json {
        let path = if path.is_absolute() {
            path
        } else {
            root.join(path)
        };
        if let Err(e) = std::fs::write(&path, report.to_json()) {
            eprintln!("spade-lint: writing {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    eprintln!(
        "spade-lint: {} files, {} finding(s), {} suppressed",
        report.files_scanned,
        report.findings.len(),
        report.suppressed.len());
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
