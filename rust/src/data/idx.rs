//! IDX (LeCun MNIST format) loader — when real MNIST files are present
//! (`train-images-idx3-ubyte` etc.), Fig. 4 evaluation can run on them
//! instead of the synthetic stand-ins (DESIGN.md §1 notes real IDX data
//! is auto-used if present).
//!
//! Format: u32 magic (0x0000_0803 for u8 3-D images, 0x0000_0801 for
//! labels), big-endian dims, raw u8 payload.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::spdd::Dataset;

fn read_be_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_be_bytes(b))
}

/// Load an IDX image file (`magic 0x803`, dims \[n, h, w\]).
pub fn load_images(path: &Path) -> Result<(Vec<f32>, usize, usize,
                                           usize)> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let magic = read_be_u32(&mut f)?;
    if magic != 0x0803 {
        bail!("{}: bad image magic {magic:#x}", path.display());
    }
    let n = read_be_u32(&mut f)? as usize;
    let h = read_be_u32(&mut f)? as usize;
    let w = read_be_u32(&mut f)? as usize;
    let mut raw = vec![0u8; n * h * w];
    f.read_exact(&mut raw)?;
    let data = raw.iter().map(|&b| b as f32 / 255.0).collect();
    Ok((data, n, h, w))
}

/// Load an IDX label file (`magic 0x801`).
pub fn load_labels(path: &Path) -> Result<Vec<u8>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let magic = read_be_u32(&mut f)?;
    if magic != 0x0801 {
        bail!("{}: bad label magic {magic:#x}", path.display());
    }
    let n = read_be_u32(&mut f)? as usize;
    let mut raw = vec![0u8; n];
    f.read_exact(&mut raw)?;
    Ok(raw)
}

/// Assemble a [`Dataset`] from an IDX image/label pair.
pub fn load_pair(images: &Path, labels: &Path, nclasses: usize)
                 -> Result<Dataset> {
    let (data, n, h, w) = load_images(images)?;
    let labels = load_labels(labels)?;
    if labels.len() != n {
        bail!("image/label count mismatch: {n} vs {}", labels.len());
    }
    Ok(Dataset { n, h, w, c: 1, nclasses, labels, data })
}

/// If real MNIST IDX files exist under `dir`, load the test split.
pub fn try_real_mnist(dir: &Path) -> Option<Dataset> {
    let img = dir.join("t10k-images-idx3-ubyte");
    let lab = dir.join("t10k-labels-idx1-ubyte");
    if img.is_file() && lab.is_file() {
        load_pair(&img, &lab, 10).ok()
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_idx_pair(dir: &Path) {
        // 2 images of 2x3 + labels
        let mut f = std::fs::File::create(
            dir.join("t10k-images-idx3-ubyte")).unwrap();
        f.write_all(&0x0803u32.to_be_bytes()).unwrap();
        f.write_all(&2u32.to_be_bytes()).unwrap();
        f.write_all(&2u32.to_be_bytes()).unwrap();
        f.write_all(&3u32.to_be_bytes()).unwrap();
        f.write_all(&[0, 51, 102, 153, 204, 255,
                      255, 204, 153, 102, 51, 0]).unwrap();
        let mut f = std::fs::File::create(
            dir.join("t10k-labels-idx1-ubyte")).unwrap();
        f.write_all(&0x0801u32.to_be_bytes()).unwrap();
        f.write_all(&2u32.to_be_bytes()).unwrap();
        f.write_all(&[7, 3]).unwrap();
    }

    #[test]
    fn round_trips_idx_pair() {
        let dir = std::env::temp_dir().join("spade_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_idx_pair(&dir);
        let ds = try_real_mnist(&dir).expect("pair should load");
        assert_eq!((ds.n, ds.h, ds.w, ds.c), (2, 2, 3, 1));
        assert_eq!(ds.labels, vec![7, 3]);
        assert_eq!(ds.data[0], 0.0);
        assert_eq!(ds.data[5], 1.0);
        assert!((ds.data[1] - 0.2).abs() < 1e-6);
    }

    #[test]
    fn rejects_wrong_magic() {
        let dir = std::env::temp_dir().join("spade_idx_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad");
        std::fs::write(&p, 0xdeadbeefu32.to_be_bytes()).unwrap();
        assert!(load_images(&p).is_err());
        assert!(load_labels(&p).is_err());
    }

    #[test]
    fn absent_files_return_none() {
        assert!(try_real_mnist(Path::new("/nonexistent")).is_none());
    }
}
