//! SPDD dataset container (little-endian):
//! `magic 'SPDD', u32 version=1, u32 n, u32 h, u32 w, u32 c,
//! u32 nclasses, u8 labels[n], f32 data[n*h*w*c]` (NHWC, range 0..1).
//!
//! Mirror of `python/compile/datasets.py::write_spdd` — the datasets are
//! generated once at build time so training (python) and evaluation
//! (rust) see bit-identical pixels.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A labelled image dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Image count.
    pub n: usize,
    /// Height, width, channels.
    pub h: usize,
    /// Width.
    pub w: usize,
    /// Channels.
    pub c: usize,
    /// Number of classes.
    pub nclasses: usize,
    /// Labels, length `n`.
    pub labels: Vec<u8>,
    /// Pixels, NHWC row-major, length `n*h*w*c`.
    pub data: Vec<f32>,
}

impl Dataset {
    /// Load an SPDD file.
    pub fn load(path: &Path) -> Result<Dataset> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"SPDD" {
            bail!("{}: bad magic {magic:?}", path.display());
        }
        let mut hdr = [0u8; 24];
        f.read_exact(&mut hdr)?;
        let rd = |i: usize| {
            u32::from_le_bytes(hdr[i * 4..i * 4 + 4].try_into().unwrap())
                as usize
        };
        let (ver, n, h, w, c, nclasses) =
            (rd(0), rd(1), rd(2), rd(3), rd(4), rd(5));
        if ver != 1 {
            bail!("unsupported SPDD version {ver}");
        }
        let mut labels = vec![0u8; n];
        f.read_exact(&mut labels)?;
        let mut raw = vec![0u8; n * h * w * c * 4];
        f.read_exact(&mut raw)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        Ok(Dataset { n, h, w, c, nclasses, labels, data })
    }

    /// Load `artifacts/data/<name>_<split>.bin`.
    pub fn load_artifact(name: &str, split: &str) -> Result<Dataset> {
        let p = crate::artifacts_dir()
            .join("data")
            .join(format!("{name}_{split}.bin"));
        Self::load(&p)
    }

    /// One image as an f32 slice (HWC).
    pub fn image(&self, i: usize) -> &[f32] {
        let sz = self.h * self.w * self.c;
        &self.data[i * sz..(i + 1) * sz]
    }

    /// A batch of images as a contiguous NHWC buffer.
    pub fn batch(&self, start: usize, count: usize) -> (Vec<f32>, &[u8]) {
        let sz = self.h * self.w * self.c;
        let end = (start + count).min(self.n);
        (
            self.data[start * sz..end * sz].to_vec(),
            &self.labels[start..end],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        crate::artifacts_dir().join("data").is_dir()
    }

    #[test]
    fn loads_mnist_syn() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let ds = Dataset::load_artifact("mnist_syn", "test").unwrap();
        assert_eq!((ds.h, ds.w, ds.c), (28, 28, 1));
        assert_eq!(ds.nclasses, 10);
        assert_eq!(ds.labels.len(), ds.n);
        assert_eq!(ds.data.len(), ds.n * 28 * 28);
        assert!(ds.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(ds.labels.iter().all(|&l| l < 10));
    }

    #[test]
    fn batch_slicing() {
        if !have_artifacts() {
            return;
        }
        let ds = Dataset::load_artifact("alpha_syn", "test").unwrap();
        let (pix, lab) = ds.batch(3, 5);
        assert_eq!(lab.len(), 5);
        assert_eq!(pix.len(), 5 * ds.h * ds.w * ds.c);
        assert_eq!(&pix[..4], &ds.image(3)[..4]);
    }
}
