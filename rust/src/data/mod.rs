//! Dataset access: the SPDD binary container written at build time by
//! `python/compile/datasets.py` (synthetic MNIST/CIFAR/alphabet
//! stand-ins — DESIGN.md §1), plus a synthetic request-traffic generator
//! for the serving coordinator.

pub mod idx;
pub mod spdd;
pub mod traffic;

pub use spdd::Dataset;
pub use traffic::TrafficGen;
