//! Dataset access: the SPDD binary container written at build time by
//! `python/compile/datasets.py` (synthetic MNIST/CIFAR/alphabet
//! stand-ins — DESIGN.md §1), a synthetic request-traffic generator
//! for the serving coordinator, and the Matrix Market (`.mtx`)
//! coordinate reader/writer + synthetic-sparsity generator feeding
//! the sparse SpGEMM path ([`mtx`]).

pub mod idx;
pub mod mtx;
pub mod spdd;
pub mod traffic;

pub use mtx::{synthetic_sparse, MtxMatrix};
pub use spdd::Dataset;
pub use traffic::TrafficGen;
