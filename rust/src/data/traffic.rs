//! Synthetic inference-request traffic for the serving coordinator:
//! Poisson-ish arrivals, mixed precision demands, dataset-backed or
//! random payloads. Deterministic (SplitMix64) so latency benches are
//! reproducible.

use crate::engine::Mode;
use crate::util::SplitMix64;

/// One synthetic inference request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Monotonic id.
    pub id: u64,
    /// Arrival time offset from stream start, microseconds.
    pub arrival_us: u64,
    /// Input payload (flattened image).
    pub input: Vec<f32>,
    /// Precision demanded by the client (None = router's choice).
    pub mode: Option<Mode>,
}

/// Deterministic request generator.
#[derive(Debug)]
pub struct TrafficGen {
    rng: SplitMix64,
    next_id: u64,
    clock_us: u64,
    /// Mean inter-arrival gap (microseconds).
    pub mean_gap_us: u64,
    /// Payload length.
    pub input_len: usize,
}

impl TrafficGen {
    /// Generator with mean arrival gap and payload size.
    pub fn new(seed: u64, mean_gap_us: u64, input_len: usize) -> Self {
        Self { rng: SplitMix64::new(seed), next_id: 0, clock_us: 0,
               mean_gap_us, input_len }
    }

    /// Next request (exponential-ish gap, random payload, 25 % of
    /// requests pin an explicit precision).
    pub fn next(&mut self) -> Request {
        // geometric approximation of exponential inter-arrival
        let u = self.rng.f64().max(1e-12);
        let gap = (-u.ln() * self.mean_gap_us as f64) as u64;
        self.clock_us += gap.max(1);
        let input: Vec<f32> =
            (0..self.input_len).map(|_| self.rng.f32()).collect();
        let mode = match self.rng.below(8) {
            0 => Some(Mode::P8x4),
            1 => Some(Mode::P16x2),
            _ => None,
        };
        let r = Request { id: self.next_id, arrival_us: self.clock_us,
                          input, mode };
        self.next_id += 1;
        r
    }

    /// Generate a burst of `n` requests.
    pub fn burst(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_monotone() {
        let mut a = TrafficGen::new(1, 100, 16);
        let mut b = TrafficGen::new(1, 100, 16);
        let ra = a.burst(50);
        let rb = b.burst(50);
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival_us, y.arrival_us);
            assert_eq!(x.input, y.input);
        }
        for w in ra.windows(2) {
            assert!(w[1].arrival_us > w[0].arrival_us);
        }
    }

    #[test]
    fn mean_gap_approximate() {
        let mut g = TrafficGen::new(2, 1000, 4);
        let rs = g.burst(2000);
        let total = rs.last().unwrap().arrival_us;
        let mean = total as f64 / 2000.0;
        assert!((mean - 1000.0).abs() < 150.0, "mean {mean}");
    }
}
