//! Matrix Market (`.mtx`) coordinate reader/writer + a synthetic
//! sparsity generator — the ingest edge of the sparse SpGEMM path.
//!
//! Only the plain `matrix coordinate real general` flavor is
//! supported (1-based COO triplets); anything else — `complex`,
//! `pattern`, `symmetric`, `array` — is an explicit error rather
//! than a silent misread. Parsed matrices convert losslessly to a
//! dense row-major buffer ([`MtxMatrix::to_dense_f32`]) or straight
//! to a CSR kernel operand ([`MtxMatrix::to_plan`] →
//! [`crate::kernel::SparsePlan::from_csr`], which re-validates the
//! structure: ascending, de-duplicated, in-range).
//!
//! Writing uses Rust's shortest-round-trip float formatting, so
//! `parse(write(m)) == m` exactly (`mtx_round_trips` pins this).

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::kernel::SparsePlan;
use crate::posit::{from_f64, PositFormat};
use crate::util::SplitMix64;

/// The one header this reader accepts.
const BANNER: &str = "%%MatrixMarket matrix coordinate real general";

/// A coordinate-format sparse matrix: 0-based `(row, col, value)`
/// triplets in file order plus the declared shape.
#[derive(Debug, Clone, PartialEq)]
pub struct MtxMatrix {
    /// Declared row count.
    pub rows: usize,
    /// Declared column count.
    pub cols: usize,
    /// 0-based entries, exactly as many as the size line declared.
    pub entries: Vec<(usize, usize, f64)>,
}

impl MtxMatrix {
    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Stored fraction: `nnz / (rows * cols)` (0 for empty shapes).
    pub fn density(&self) -> f64 {
        let total = self.rows * self.cols;
        if total == 0 {
            0.0
        } else {
            self.nnz() as f64 / total as f64
        }
    }

    /// Densify to a row-major f32 buffer (the dense-oracle operand).
    pub fn to_dense_f32(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for &(r, c, v) in &self.entries {
            out[r * self.cols + c] = v as f32;
        }
        out
    }

    /// Quantize the stored values to `fmt` and build a validated CSR
    /// [`SparsePlan`] (entries are sorted here; `from_csr` still
    /// rejects duplicates and out-of-range indices).
    pub fn to_plan(&self, fmt: PositFormat) -> Result<SparsePlan> {
        let mut sorted = self.entries.clone();
        sorted.sort_by_key(|&(r, c, _)| (r, c));
        let mut row_ptr = vec![0usize; self.rows + 1];
        let mut col_idx = Vec::with_capacity(sorted.len());
        let mut words = Vec::with_capacity(sorted.len());
        for &(r, c, v) in &sorted {
            ensure!(r < self.rows && c < self.cols,
                    "entry ({r}, {c}) outside {}x{}", self.rows,
                    self.cols);
            row_ptr[r + 1] += 1;
            col_idx.push(c);
            words.push(from_f64(v, fmt));
        }
        for r in 0..self.rows {
            row_ptr[r + 1] += row_ptr[r];
        }
        SparsePlan::from_csr(self.rows, self.cols, row_ptr, col_idx,
                             words, fmt)
            .map_err(|e| anyhow::anyhow!("mtx -> CSR: {e}"))
    }

    /// Parse Matrix Market coordinate text. Errors on a wrong or
    /// unsupported banner, a malformed size line, non-numeric or
    /// short triplet lines, 1-based indices outside the declared
    /// shape, and truncated or over-long files (entry count must
    /// match the size line exactly).
    pub fn parse(src: &str) -> Result<MtxMatrix> {
        let mut lines = src.lines();
        let banner = lines.next().context("empty .mtx input")?;
        let got: Vec<&str> =
            banner.split_whitespace().collect();
        let want: Vec<&str> = BANNER.split_whitespace().collect();
        ensure!(!got.is_empty() && got[0] == want[0],
                "bad .mtx banner {banner:?}");
        ensure!(got == want,
                "unsupported .mtx flavor {banner:?} \
                 (only {BANNER:?})");
        // Comment lines (%...) and blank lines may precede the size
        // line; after it, exactly nnz triplet lines must follow.
        let mut body = lines
            .filter(|l| !l.trim().is_empty()
                        && !l.trim_start().starts_with('%'));
        let size = body.next().context("missing .mtx size line")?;
        let dims: Vec<&str> = size.split_whitespace().collect();
        ensure!(dims.len() == 3, "bad .mtx size line {size:?}");
        let rows: usize = dims[0].parse()
            .with_context(|| format!("bad row count {:?}", dims[0]))?;
        let cols: usize = dims[1].parse()
            .with_context(|| format!("bad col count {:?}", dims[1]))?;
        let nnz: usize = dims[2].parse()
            .with_context(|| format!("bad nnz count {:?}", dims[2]))?;
        let mut entries = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            let line = body.next().with_context(|| {
                format!("truncated .mtx: {} of {nnz} entries",
                        entries.len())
            })?;
            let f: Vec<&str> = line.split_whitespace().collect();
            ensure!(f.len() == 3, "bad .mtx entry line {line:?}");
            let r: usize = f[0].parse()
                .with_context(|| format!("bad row index {:?}", f[0]))?;
            let c: usize = f[1].parse()
                .with_context(|| format!("bad col index {:?}", f[1]))?;
            let v: f64 = f[2].parse()
                .with_context(|| format!("bad value {:?}", f[2]))?;
            ensure!(r >= 1 && r <= rows && c >= 1 && c <= cols,
                    "entry ({r}, {c}) outside 1..={rows} x 1..={cols}");
            entries.push((r - 1, c - 1, v));
        }
        if let Some(extra) = body.next() {
            bail!("trailing .mtx data after {nnz} entries: {extra:?}");
        }
        Ok(MtxMatrix { rows, cols, entries })
    }

    /// Render back to Matrix Market text (1-based, shortest
    /// round-trip floats) — the inverse of [`MtxMatrix::parse`].
    pub fn write(&self) -> String {
        let mut out = String::new();
        out.push_str(BANNER);
        out.push('\n');
        out.push_str(&format!("{} {} {}\n", self.rows, self.cols,
                              self.nnz()));
        for &(r, c, v) in &self.entries {
            out.push_str(&format!("{} {} {v}\n", r + 1, c + 1));
        }
        out
    }

    /// Read + parse a `.mtx` file.
    pub fn load(path: &Path) -> Result<MtxMatrix> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        MtxMatrix::parse(&src)
            .with_context(|| format!("parse {}", path.display()))
    }
}

/// Deterministic synthetic sparsity: each cell is stored with
/// probability `density` (independent Bernoulli, SplitMix64-seeded),
/// values drawn from the same wide exponent range the kernel property
/// tests use. Stored values are never 0.0, so the realized density of
/// the quantized matrix matches the structural one at every posit
/// width.
pub fn synthetic_sparse(rows: usize, cols: usize, density: f64,
                        seed: u64) -> MtxMatrix {
    let mut rng = SplitMix64::new(seed);
    let per_mille = (density * 1000.0).clamp(0.0, 1000.0) as u64;
    let mut entries = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if rng.below(1000) < per_mille {
                let mut v = rng.wide(-4, 4);
                if v == 0.0 {
                    v = 1.0;
                }
                entries.push((r, c, v));
            }
        }
    }
    MtxMatrix { rows, cols, entries }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::P16_FMT;

    #[test]
    fn mtx_round_trips() {
        let m = synthetic_sparse(13, 9, 0.2, 42);
        let back = MtxMatrix::parse(&m.write()).unwrap();
        assert_eq!(back, m);
        assert!(m.nnz() > 0);
        assert!((m.density() - 0.2).abs() < 0.15);
    }

    #[test]
    fn empty_and_full_density() {
        let none = synthetic_sparse(6, 6, 0.0, 1);
        assert_eq!(none.nnz(), 0);
        assert_eq!(none.density(), 0.0);
        let all = synthetic_sparse(6, 6, 1.0, 1);
        assert_eq!(all.nnz(), 36);
        let back = MtxMatrix::parse(&none.write()).unwrap();
        assert_eq!(back.entries, vec![]);
    }

    #[test]
    fn dense_and_plan_agree() {
        let m = synthetic_sparse(7, 5, 0.4, 7);
        let p = m.to_plan(P16_FMT).unwrap();
        assert_eq!(p.rows, 7);
        assert_eq!(p.cols, 5);
        assert_eq!(p.nnz(), m.nnz());
        // Densifying the plan lands every quantized value at its
        // coordinate; `to_plan` quantizes f64 -> posit directly, so
        // the oracle here is `from_f64`, not an f32 staging buffer
        // (f32 would double-round).
        let mut want = vec![0u64; 7 * 5];
        for &(r, c, v) in &m.entries {
            want[r * 5 + c] = from_f64(v, P16_FMT);
        }
        assert_eq!(p.densify().words, want);
        // The f32 staging buffer still carries the exact sparsity
        // pattern (posit encoding never flushes a nonzero to zero).
        let d = m.to_dense_f32();
        for i in 0..want.len() {
            assert_eq!(d[i] != 0.0, want[i] != 0, "cell {i}");
        }
    }

    #[test]
    fn parse_rejects_malformed_input() {
        // Wrong banner entirely.
        assert!(MtxMatrix::parse("hello\n1 1 0\n").is_err());
        // Right magic, unsupported flavor.
        let sym = "%%MatrixMarket matrix coordinate real symmetric\n\
                   2 2 1\n1 1 3.0\n";
        let err = MtxMatrix::parse(sym).unwrap_err().to_string();
        assert!(err.contains("unsupported"), "{err}");
        // Missing size line.
        assert!(MtxMatrix::parse(BANNER).is_err());
        // Malformed size line.
        let bad = format!("{BANNER}\n2 2\n");
        assert!(MtxMatrix::parse(&bad).is_err());
        // Truncated: promises 2 entries, delivers 1.
        let trunc = format!("{BANNER}\n2 2 2\n1 1 3.0\n");
        let err =
            MtxMatrix::parse(&trunc).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        // Trailing extra entry.
        let extra =
            format!("{BANNER}\n2 2 1\n1 1 3.0\n2 2 4.0\n");
        assert!(MtxMatrix::parse(&extra).is_err());
        // Out-of-range 1-based index (0 and too-large).
        let zero = format!("{BANNER}\n2 2 1\n0 1 3.0\n");
        assert!(MtxMatrix::parse(&zero).is_err());
        let big = format!("{BANNER}\n2 2 1\n1 3 3.0\n");
        assert!(MtxMatrix::parse(&big).is_err());
        // Non-numeric value.
        let nan = format!("{BANNER}\n2 2 1\n1 1 pizza\n");
        assert!(MtxMatrix::parse(&nan).is_err());
    }

    #[test]
    fn to_plan_rejects_duplicates() {
        let m = MtxMatrix {
            rows: 2,
            cols: 2,
            entries: vec![(0, 0, 1.0), (0, 0, 2.0)],
        };
        let err = m.to_plan(P16_FMT).unwrap_err().to_string();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn comments_and_blank_lines_skip() {
        let src = format!(
            "{BANNER}\n% a comment\n\n3 3 2\n% another\n\
             1 2 1.5\n3 3 -2.25\n");
        let m = MtxMatrix::parse(&src).unwrap();
        assert_eq!(m.entries,
                   vec![(0, 1, 1.5), (2, 2, -2.25)]);
    }
}
