//! Multi-stage logarithmic barrel shifter with SIMD lane isolation
//! (Fig. 2c).
//!
//! The RTL implements shifts as log2(W) mux stages (shift-by-1, -2, -4,
//! ...), each stage gated per lane so bits never cross a lane boundary
//! in P8/P16 modes. We reproduce the stage structure: every stage is a
//! conditional lane-masked shift, and the test suite checks equivalence
//! with plain per-lane shifts for all modes, amounts, and directions.

use super::Mode;

/// Shift direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    /// Logical left shift (field extraction after regime strip).
    Left,
    /// Logical right shift.
    Right,
    /// Arithmetic right shift (quire alignment preserves sign).
    ArithRight,
}

/// Lane-isolated logarithmic barrel shift.
///
/// `amounts[i]` is the shift for lane `i`; amounts >= lane width drain
/// the lane (to 0, or to the sign fill for [`Dir::ArithRight`]).
pub fn simd_shift(x: u32, amounts: &[u32], dir: Dir, mode: Mode) -> u32 {
    debug_assert_eq!(amounts.len(), mode.lanes());
    let w = mode.lane_bits();

    // fixed-size scratch: this sits on the engine's per-MAC hot path
    let mut lanes = [0u32; 4];
    for (i, l) in lanes.iter_mut().enumerate().take(mode.lanes()) {
        *l = super::lane_extract(x, mode, i) as u32;
    }
    let lanes = &mut lanes[..mode.lanes()];

    // log2(W) mux stages; stage k shifts by 2^k when the amount bit is
    // set. Amounts saturate at the lane width (drain).
    let stages = w.trailing_zeros(); // 3, 4, or 5
    for (i, lane) in lanes.iter_mut().enumerate() {
        let amt = amounts[i].min(w); // saturate
        let sign = if w == 32 { *lane >> 31 } else { (*lane >> (w - 1)) & 1 };
        let lane_mask: u32 =
            if w == 32 { u32::MAX } else { (1u32 << w) - 1 };
        let mut v = *lane & lane_mask;
        for k in 0..=stages {
            let step = 1u32 << k;
            if amt & step != 0 {
                v = match dir {
                    Dir::Left => {
                        if step >= w { 0 } else { (v << step) & lane_mask }
                    }
                    Dir::Right => {
                        if step >= w { 0 } else { v >> step }
                    }
                    Dir::ArithRight => {
                        if step >= w {
                            if sign == 1 { lane_mask } else { 0 }
                        } else {
                            let shifted = v >> step;
                            if sign == 1 {
                                // fill vacated high bits with sign
                                let fill = ((1u32 << step) - 1)
                                    << (w - step);
                                (shifted | fill) & lane_mask
                            } else {
                                shifted
                            }
                        }
                    }
                };
            }
        }
        *lane = v;
    }

    let mut out = 0u32;
    for (i, &l) in lanes.iter().enumerate() {
        out = super::lane_insert(out, mode, i, l as u64);
    }
    out
}

/// Oracle: ordinary per-lane shift.
pub fn reference(x: u32, amounts: &[u32], dir: Dir, mode: Mode) -> u32 {
    let w = mode.lane_bits();
    let mask: u64 = if w == 32 { 0xFFFF_FFFF } else { (1u64 << w) - 1 };
    let mut out = 0u32;
    for i in 0..mode.lanes() {
        let lane = super::lane_extract(x, mode, i);
        let amt = amounts[i].min(w);
        let v = match dir {
            Dir::Left => {
                if amt >= w { 0 } else { (lane << amt) & mask }
            }
            Dir::Right => {
                if amt >= w { 0 } else { lane >> amt }
            }
            Dir::ArithRight => {
                let sx = ((lane << (64 - w)) as i64) >> (64 - w);
                if amt >= w {
                    if sx < 0 { mask } else { 0 }
                } else {
                    ((sx >> amt) as u64) & mask
                }
            }
        };
        out = super::lane_insert(out, mode, i, v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn matches_reference_exhaustive_amounts() {
        let mut rng = SplitMix64::new(4);
        for _ in 0..20_000 {
            let x = rng.next_u64() as u32;
            for mode in Mode::ALL {
                let w = mode.lane_bits();
                for dir in [Dir::Left, Dir::Right, Dir::ArithRight] {
                    let amounts: Vec<u32> = (0..mode.lanes())
                        .map(|_| rng.below(w as u64 + 2) as u32)
                        .collect();
                    assert_eq!(
                        simd_shift(x, &amounts, dir, mode),
                        reference(x, &amounts, dir, mode),
                        "x={x:#x} mode={mode:?} dir={dir:?} amt={amounts:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn bits_never_cross_lanes() {
        // All-ones lane 0 shifted left must not spill into lane 1.
        let x = 0x0000_00FFu32;
        let out = simd_shift(x, &[4, 0, 0, 0], Dir::Left, Mode::P8x4);
        assert_eq!(out, 0x0000_00F0);
        // P16: left shift of lane 0 stays under bit 16
        let out = simd_shift(0x0000_FFFF, &[8, 0], Dir::Left, Mode::P16x2);
        assert_eq!(out, 0x0000_FF00);
    }

    #[test]
    fn arithmetic_right_fills_sign() {
        // lane with MSB set, shift 3: high bits fill with 1s
        let out = simd_shift(0x80, &[3, 0, 0, 0], Dir::ArithRight,
                             Mode::P8x4);
        assert_eq!(out & 0xFF, 0xF0);
        // full-width P32
        let out = simd_shift(0x8000_0000, &[4], Dir::ArithRight,
                             Mode::P32x1);
        assert_eq!(out, 0xF800_0000);
    }

    #[test]
    fn full_drain() {
        for mode in Mode::ALL {
            let amounts: Vec<u32> =
                vec![mode.lane_bits() + 1; mode.lanes()];
            assert_eq!(simd_shift(0xDEAD_BEEF, &amounts, Dir::Left, mode),
                       0);
            assert_eq!(simd_shift(0xDEAD_BEEF, &amounts, Dir::Right, mode),
                       0);
        }
    }
}
