//! SIMD Leading-One Detector (Fig. 2a).
//!
//! The RTL builds a 32-bit LOD from four 8-bit LOD blocks whose
//! valid/position outputs are combined pairwise by mode multiplexers:
//! in P8 mode each block reports its own lane; in P16 mode pairs fuse
//! (high block wins, else low block + 8); in P32 all four fuse. This
//! module reproduces that gate-level composition literally — `lod8` is
//! a priority encoder and the fusion layers are the 2:1 mux trees —
//! so the cost model can count the same structure the simulator runs.

use super::Mode;

/// Output of one LOD block: `valid` and the bit position of the leading
/// one within the block (block-local, MSB-relative position in the RTL;
/// we report the absolute bit index from the lane's LSB for convenience).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LodOut {
    /// True if any bit in the segment is set.
    pub valid: bool,
    /// Index (from segment LSB) of the most significant set bit.
    pub pos: u32,
}

/// 8-bit leading-one detector: the leaf block of the hierarchy.
#[inline]
pub fn lod8(x: u8) -> LodOut {
    if x == 0 {
        LodOut { valid: false, pos: 0 }
    } else {
        LodOut { valid: true, pos: 7 - x.leading_zeros() }
    }
}

/// Fuse two adjacent LOD results (hi covers bits [w..2w), lo [0..w)).
#[inline]
pub fn lod_fuse(hi: LodOut, lo: LodOut, w: u32) -> LodOut {
    if hi.valid {
        LodOut { valid: true, pos: hi.pos + w }
    } else {
        LodOut { valid: lo.valid, pos: lo.pos }
    }
}

/// SIMD LOD over a packed 32-bit word: per active lane, the position of
/// the leading one (used for regime decode and quire renormalization).
pub fn simd_lod(x: u32, mode: Mode) -> Vec<LodOut> {
    simd_lod4(x, mode)[..mode.lanes()].to_vec()
}

/// Allocation-free variant for the pipeline hot path: results in the
/// first `mode.lanes()` slots, the rest zeroed.
#[inline]
pub fn simd_lod4(x: u32, mode: Mode) -> [LodOut; 4] {
    let b: [u8; 4] = x.to_le_bytes();
    let l = [lod8(b[0]), lod8(b[1]), lod8(b[2]), lod8(b[3])];
    let zero = LodOut { valid: false, pos: 0 };
    match mode {
        Mode::P8x4 => l,
        Mode::P16x2 => [
            lod_fuse(l[1], l[0], 8),
            lod_fuse(l[3], l[2], 8),
            zero,
            zero,
        ],
        Mode::P32x1 => {
            let lo16 = lod_fuse(l[1], l[0], 8);
            let hi16 = lod_fuse(l[3], l[2], 8);
            [lod_fuse(hi16, lo16, 16), zero, zero, zero]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn lod8_matches_leading_zeros() {
        for x in 0u16..=255 {
            let x = x as u8;
            let out = lod8(x);
            if x == 0 {
                assert!(!out.valid);
            } else {
                assert!(out.valid);
                assert_eq!(out.pos, 7 - x.leading_zeros());
            }
        }
    }

    #[test]
    fn simd_matches_reference_all_modes() {
        let mut rng = SplitMix64::new(1);
        for _ in 0..100_000 {
            let x = rng.next_u64() as u32;
            for mode in Mode::ALL {
                let outs = simd_lod(x, mode);
                let w = mode.lane_bits();
                for (i, o) in outs.iter().enumerate() {
                    let lane = super::super::lane_extract(x, mode, i);
                    if lane == 0 {
                        assert!(!o.valid);
                    } else {
                        assert!(o.valid);
                        assert_eq!(o.pos,
                                   w - 1 - (lane << (64 - w))
                                       .leading_zeros() as u32
                                       % 64,
                                   "x={x:#x} mode={mode:?} lane={i}");
                    }
                }
            }
        }
    }

    #[test]
    fn fused_p32_spans_lanes() {
        // leading one in byte 2 must be found by the fused 32-bit LOD
        let out = simd_lod(0x0004_0000, Mode::P32x1);
        assert_eq!(out[0], LodOut { valid: true, pos: 18 });
        // but in P8 mode lanes 0,1,3 are invalid and lane 2 reports 2
        let out = simd_lod(0x0004_0000, Mode::P8x4);
        assert!(!out[0].valid && !out[1].valid && !out[3].valid);
        assert_eq!(out[2], LodOut { valid: true, pos: 2 });
    }
}
