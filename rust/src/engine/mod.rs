//! Bit-accurate model of the SPADE SIMD Posit MAC datapath (Fig. 1/2).
//!
//! The RTL's functional contract — which output bits appear for which
//! input bits, per MODE — is reproduced exactly, structured the way the
//! paper structures the hardware:
//!
//! * [`Mode`] — the 2-bit MODE signal: 4 independent Posit-8 lanes,
//!   2 paired Posit-16 lanes, or 1 fused Posit-32 datapath.
//! * [`lod`] — SIMD Leading-One Detector (Fig. 2a), built hierarchically
//!   from 8-bit blocks exactly as the RTL fuses lanes.
//! * [`complementor`] — mode-aware two's complementor (Fig. 2b): carry
//!   chains are cut at lane boundaries in P8 mode, fused pairwise in
//!   P16, full-width in P32.
//! * [`shifter`] — multi-stage logarithmic barrel shifter (Fig. 2c) with
//!   per-lane isolation masks.
//! * [`booth`] — radix-4 modified Booth mantissa multiplier in 8/16/32
//!   partition modes (Fig. 2d-f): one shared partial-product array whose
//!   diagonal blocks host the lanes.
//! * [`pipeline`] — the five-stage MAC pipeline of §II-B: unpack ->
//!   multiply -> quire accumulate -> normalize -> round/pack, with
//!   per-stage registers, enable/bypass gating, and activity counters
//!   that feed the energy model.
//!
//! Verification: `rust/tests/engine_vs_posit.rs` drives every MODE
//! against the golden [`crate::posit`] core (quire + RNE encode) and
//! requires bit-exact agreement — the reproduction of the paper's
//! "exact agreement with SoftPosit over randomized vectors" claim.

pub mod booth;
pub mod complementor;
pub mod lod;
pub mod pipeline;
pub mod shifter;

pub use pipeline::{MacEngine, StageActivity};

use crate::posit::{PositFormat, P16_FMT, P32_FMT, P8_FMT};

/// The 2-bit MODE signal selecting the SIMD configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Mode {
    /// Four independent Posit(8,0) lanes per 32-bit word.
    P8x4,
    /// Two paired Posit(16,1) lanes per 32-bit word.
    P16x2,
    /// One fused Posit(32,2) datapath.
    P32x1,
}

impl Mode {
    /// Number of active SIMD lanes.
    #[inline]
    pub const fn lanes(self) -> usize {
        match self {
            Mode::P8x4 => 4,
            Mode::P16x2 => 2,
            Mode::P32x1 => 1,
        }
    }

    /// Lane width in bits.
    #[inline]
    pub const fn lane_bits(self) -> u32 {
        match self {
            Mode::P8x4 => 8,
            Mode::P16x2 => 16,
            Mode::P32x1 => 32,
        }
    }

    /// Posit format processed per lane.
    #[inline]
    pub const fn format(self) -> PositFormat {
        match self {
            Mode::P8x4 => P8_FMT,
            Mode::P16x2 => P16_FMT,
            Mode::P32x1 => P32_FMT,
        }
    }

    /// Canonical short tag ("p8" / "p16" / "p32") — the single source
    /// for metric keys, bench labels and stats rows.
    #[inline]
    pub const fn tag(self) -> &'static str {
        match self {
            Mode::P8x4 => "p8",
            Mode::P16x2 => "p16",
            Mode::P32x1 => "p32",
        }
    }

    /// All modes, for sweeps.
    pub const ALL: [Mode; 3] = [Mode::P8x4, Mode::P16x2, Mode::P32x1];
}

/// Extract lane `i` from a packed 32-bit operand word.
#[inline]
pub fn lane_extract(word: u32, mode: Mode, i: usize) -> u64 {
    let w = mode.lane_bits();
    let mask = if w == 32 { u32::MAX } else { (1u32 << w) - 1 };
    ((word >> (w * i as u32)) & mask) as u64
}

/// Insert lane `i` into a packed 32-bit word.
#[inline]
pub fn lane_insert(word: u32, mode: Mode, i: usize, lane: u64) -> u32 {
    let w = mode.lane_bits();
    let mask = if w == 32 { u32::MAX } else { (1u32 << w) - 1 };
    let shift = w * i as u32;
    (word & !(mask << shift)) | (((lane as u32) & mask) << shift)
}

/// Pack a slice of lane words into a 32-bit SIMD word.
pub fn pack_lanes(lanes: &[u64], mode: Mode) -> u32 {
    debug_assert_eq!(lanes.len(), mode.lanes());
    let mut w = 0u32;
    for (i, &l) in lanes.iter().enumerate() {
        w = lane_insert(w, mode, i, l);
    }
    w
}

/// Unpack a 32-bit SIMD word into lane words.
pub fn unpack_lanes(word: u32, mode: Mode) -> Vec<u64> {
    (0..mode.lanes()).map(|i| lane_extract(word, mode, i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_round_trip() {
        for mode in Mode::ALL {
            let lanes: Vec<u64> =
                (0..mode.lanes()).map(|i| 0x11 * (i as u64 + 1)).collect();
            let packed = pack_lanes(&lanes, mode);
            assert_eq!(unpack_lanes(packed, mode), lanes);
        }
    }

    #[test]
    fn mode_constants() {
        assert_eq!(Mode::P8x4.lanes() * Mode::P8x4.lane_bits() as usize, 32);
        assert_eq!(Mode::P16x2.lanes() * Mode::P16x2.lane_bits() as usize,
                   32);
        assert_eq!(Mode::P32x1.lanes() * Mode::P32x1.lane_bits() as usize,
                   32);
    }

    #[test]
    fn lane_insert_is_masked() {
        let w = lane_insert(0xFFFF_FFFF, Mode::P8x4, 1, 0x1AB);
        assert_eq!(w, 0xFFFF_ABFF); // only lane 1 replaced, high bits cut
    }
}
