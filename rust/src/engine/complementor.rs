//! Mode-aware SIMD two's complementor (Fig. 2b).
//!
//! Stage 1 uses it to rectify negative posit words before field
//! extraction; Stage 3 uses it on the aligned mantissa products. The RTL
//! is an invert-XOR layer followed by an increment whose carry chain is
//! *segmented* by the MODE signal: no inter-lane carry in P8 mode,
//! pairwise-localized carry in P16 mode, full-width carry in P32 mode.
//! We model the carry chain bit-for-bit (nibble-group ripple, like the
//! RTL's carry-select groups) rather than calling `wrapping_neg`, so the
//! lane-isolation behaviour is the tested artifact.

use super::Mode;

/// Conditionally two's-complement each active lane of `x`.
///
/// `neg[i]` selects complementation for lane `i` (length must equal
/// `mode.lanes()`).
pub fn simd_complement(x: u32, neg: &[bool], mode: Mode) -> u32 {
    debug_assert_eq!(neg.len(), mode.lanes());
    let lane_w = mode.lane_bits();

    // Invert layer: XOR each lane with its negate control.
    let mut inverted = 0u32;
    for i in 0..mode.lanes() {
        let lane = super::lane_extract(x, mode, i) as u32;
        let v = if neg[i] { !lane } else { lane };
        inverted = super::lane_insert(inverted, mode, i,
                                      (v as u64) & ((1u64 << lane_w) - 1).min(u32::MAX as u64));
    }

    // Increment layer: per-bit ripple with carries cut at lane borders.
    let mut out = 0u32;
    let mut carry = 0u32;
    for bit in 0..32 {
        let lane_idx = (bit / lane_w) as usize;
        if bit % lane_w == 0 {
            // MODE gate: a fresh carry-in = neg for this lane's segment
            carry = neg[lane_idx] as u32;
        }
        let a = (inverted >> bit) & 1;
        let s = a ^ carry;
        carry &= a; // carry propagates only through 1-bits (a+1 ripple)
        out |= s << bit;
    }
    out
}

/// Reference lane-wise negate using ordinary integer ops (oracle).
pub fn reference(x: u32, neg: &[bool], mode: Mode) -> u32 {
    let w = mode.lane_bits();
    let mask: u64 = if w == 32 { 0xFFFF_FFFF } else { (1u64 << w) - 1 };
    let mut out = 0u32;
    for i in 0..mode.lanes() {
        let lane = super::lane_extract(x, mode, i);
        let v = if neg[i] { lane.wrapping_neg() & mask } else { lane };
        out = super::lane_insert(out, mode, i, v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn negs(bits: u32, mode: Mode) -> Vec<bool> {
        (0..mode.lanes()).map(|i| (bits >> i) & 1 == 1).collect()
    }

    #[test]
    fn matches_reference_all_modes() {
        let mut rng = SplitMix64::new(2);
        for _ in 0..100_000 {
            let x = rng.next_u64() as u32;
            for mode in Mode::ALL {
                for nb in 0..(1u32 << mode.lanes()) {
                    let n = negs(nb, mode);
                    assert_eq!(simd_complement(x, &n, mode),
                               reference(x, &n, mode),
                               "x={x:#x} mode={mode:?} neg={n:?}");
                }
            }
        }
    }

    #[test]
    fn carry_does_not_cross_lanes_in_p8() {
        // lane0 = 0x00 -> two's comp = 0x00 with carry-out that must NOT
        // increment lane1.
        let x = 0x0000_FF00u32; // lane1 = 0xFF
        let out = simd_complement(x, &[true, true, false, false],
                                  Mode::P8x4);
        assert_eq!(out & 0xFF, 0x00); // -0 = 0
        assert_eq!((out >> 8) & 0xFF, 0x01); // -0xFF = 0x01, no extra carry
    }

    #[test]
    fn carry_crosses_bytes_in_p32() {
        // -1 over the full 32-bit word
        let out = simd_complement(1, &[true], Mode::P32x1);
        assert_eq!(out, 0xFFFF_FFFF);
        // and -(0x0000_0100)
        let out = simd_complement(0x100, &[true], Mode::P32x1);
        assert_eq!(out, 0x100u32.wrapping_neg());
    }

    #[test]
    fn noop_when_not_negating() {
        let mut rng = SplitMix64::new(3);
        for _ in 0..1000 {
            let x = rng.next_u64() as u32;
            for mode in Mode::ALL {
                let n = vec![false; mode.lanes()];
                assert_eq!(simd_complement(x, &n, mode), x);
            }
        }
    }
}
