//! Partitioned radix-4 modified Booth mantissa multiplier (Fig. 2d-f).
//!
//! The RTL shares one partial-product array across precisions: in P8
//! mode four 8x8 diagonal blocks are active, in P16 mode two 16x16
//! groups, in P32 the full 32x32 aggregation. We reproduce the Booth
//! digit recoding (radix-4: digits in {-2,-1,0,+1,+2}) and the
//! block-diagonal partitioning literally; the functional result per lane
//! is the exact unsigned product of the lane mantissas.
//!
//! Posit mantissas (with the implicit leading 1) are at most 7/14/28
//! bits for P8/P16/P32, so 8/16/32-bit lane multipliers cover every
//! case with headroom.

use super::Mode;

/// Radix-4 Booth digits of an unsigned `w`-bit multiplier.
///
/// Returns ceil((w+1)/2) digits in {-2..=2}: the standard recoding of
/// overlapping triplets (b\[2i+1\], b\[2i\], b\[2i-1\]) with b\[-1\] = 0 and
/// zero-extension above bit w-1 (unsigned operand).
pub fn booth_digits(x: u64, w: u32) -> Vec<i8> {
    let n = (w + 2) / 2; // digit count covering the zero-extended MSB
    let mut out = Vec::with_capacity(n as usize);
    for i in 0..n {
        let hi = (x >> (2 * i + 1)) & 1;
        let mid = (x >> (2 * i)) & 1;
        let lo = if i == 0 { 0 } else { (x >> (2 * i - 1)) & 1 };
        let code = (hi << 2 | mid << 1 | lo) as u8;
        out.push(match code {
            0b000 | 0b111 => 0,
            0b001 | 0b010 => 1,
            0b011 => 2,
            0b100 => -2,
            0b101 | 0b110 => -1,
            _ => unreachable!(),
        });
    }
    out
}

/// One lane's Booth multiply: sum of digit-selected partial products.
///
/// Models the hardware path: each digit selects {0, ±A, ±2A} shifted by
/// 2i; the (simulated) Wallace/compressor tree reduces them to the 2w-bit
/// product. Exact for all unsigned inputs below 2^w.
pub fn booth_mul_lane(a: u64, b: u64, w: u32) -> u128 {
    debug_assert!(w == 64 || (a >> w == 0 && b >> w == 0));
    // Digit recoding inlined (no allocation — this runs once per lane
    // per MAC issue in the simulator hot path); same recode table as
    // `booth_digits`, which the tests cross-check.
    let n = (w + 2) / 2;
    let mut acc: i128 = 0;
    let mut prev = 0u64; // b[2i-1] of the current window
    for i in 0..n {
        let hi = (b >> (2 * i + 1)) & 1;
        let mid = (b >> (2 * i)) & 1;
        let code = (hi << 2) | (mid << 1) | prev;
        prev = hi;
        let pp: i128 = match code {
            0b000 | 0b111 => 0,
            0b001 | 0b010 => a as i128,
            0b011 => (a as i128) << 1,
            0b100 => -((a as i128) << 1),
            0b101 | 0b110 => -(a as i128),
            _ => unreachable!(),
        };
        acc += pp << (2 * i);
    }
    debug_assert!(acc >= 0);
    acc as u128
}

/// Result of the partitioned SIMD multiply: one product per lane.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimdProduct {
    /// Per-lane products, each `2 * lane_bits` wide.
    pub products: Vec<u128>,
    /// Number of partial products generated (for the activity model).
    pub partial_products: u32,
}

/// Partitioned Booth multiply of packed mantissa operands.
///
/// `a_lanes`/`b_lanes` carry the lane mantissas (already extracted by
/// Stage 1 — mantissas, unlike posit words, have fixed per-mode width).
pub fn simd_booth_mul(a_lanes: &[u64], b_lanes: &[u64], mode: Mode)
                      -> SimdProduct {
    debug_assert_eq!(a_lanes.len(), mode.lanes());
    debug_assert_eq!(b_lanes.len(), mode.lanes());
    let w = mode.lane_bits();
    let mut products = Vec::with_capacity(mode.lanes());
    let mut pps = 0;
    for i in 0..mode.lanes() {
        products.push(booth_mul_lane(a_lanes[i], b_lanes[i], w));
        pps += (w + 2) / 2;
    }
    SimdProduct { products, partial_products: pps }
}

/// Allocation-free variant for the pipeline hot path. Returns per-lane
/// products (unused lanes zero) and the partial-product count.
#[inline]
pub fn simd_booth_mul4(a_lanes: &[u64; 4], b_lanes: &[u64; 4],
                       mode: Mode) -> ([u128; 4], u32) {
    let w = mode.lane_bits();
    let mut products = [0u128; 4];
    let mut pps = 0;
    for i in 0..mode.lanes() {
        products[i] = booth_mul_lane(a_lanes[i], b_lanes[i], w);
        pps += (w + 2) / 2;
    }
    (products, pps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn digits_recode_value() {
        // sum(d_i * 4^i) must equal the unsigned operand
        let mut rng = SplitMix64::new(5);
        for w in [8u32, 16, 32] {
            for _ in 0..10_000 {
                let x = rng.next_u64() & ((1 << w) - 1);
                let ds = booth_digits(x, w);
                let v: i128 = ds.iter().enumerate()
                    .map(|(i, &d)| (d as i128) << (2 * i))
                    .sum();
                assert_eq!(v, x as i128, "w={w} x={x:#x} digits={ds:?}");
            }
        }
    }

    #[test]
    fn lane_mul_exhaustive_8bit() {
        for a in 0u64..256 {
            for b in 0u64..256 {
                assert_eq!(booth_mul_lane(a, b, 8), (a * b) as u128);
            }
        }
    }

    #[test]
    fn lane_mul_random_16_32() {
        let mut rng = SplitMix64::new(6);
        for _ in 0..200_000 {
            let a = rng.next_u64() & 0xFFFF;
            let b = rng.next_u64() & 0xFFFF;
            assert_eq!(booth_mul_lane(a, b, 16), (a * b) as u128);
            let a = rng.next_u64() & 0xFFFF_FFFF;
            let b = rng.next_u64() & 0xFFFF_FFFF;
            assert_eq!(booth_mul_lane(a, b, 32), (a * b) as u128);
        }
    }

    #[test]
    fn simd_partition_isolated() {
        // Products in one lane must be unaffected by other lanes.
        let mut rng = SplitMix64::new(7);
        for _ in 0..10_000 {
            for mode in Mode::ALL {
                let w = mode.lane_bits();
                let mask = if w == 64 { u64::MAX } else { (1 << w) - 1 };
                let a: Vec<u64> = (0..mode.lanes())
                    .map(|_| rng.next_u64() & mask).collect();
                let b: Vec<u64> = (0..mode.lanes())
                    .map(|_| rng.next_u64() & mask).collect();
                let out = simd_booth_mul(&a, &b, mode);
                for i in 0..mode.lanes() {
                    assert_eq!(out.products[i],
                               (a[i] as u128) * (b[i] as u128));
                }
            }
        }
    }

    #[test]
    fn partial_product_counts_match_partitioning() {
        // 4 lanes x 5 PPs (8-bit) vs 2 x 9 (16-bit) vs 1 x 17 (32-bit):
        // the shared array activates the same silicon, different gating.
        let z = [0u64, 0, 0, 0];
        assert_eq!(simd_booth_mul(&z, &z, Mode::P8x4).partial_products, 20);
        assert_eq!(simd_booth_mul(&z[..2], &z[..2], Mode::P16x2)
                       .partial_products, 18);
        assert_eq!(simd_booth_mul(&z[..1], &z[..1], Mode::P32x1)
                       .partial_products, 17);
    }
}
