//! The five-stage SPADE MAC pipeline (§II-B, Fig. 1).
//!
//! Stage 1 — Posit unpacking & field extraction (sign check, mode-aware
//!           complementor, SIMD LOD regime decode, barrel-shift field
//!           alignment);
//! Stage 2 — partitioned Booth mantissa multiplication + scale addition;
//! Stage 3 — quire accumulation (exact, enable-gated for bypass);
//! Stage 4 — reconstruction & normalization (quire LOD, regime/exponent
//!           recompute);
//! Stage 5 — round-to-nearest-even packing.
//!
//! Timing model: the pipeline is fully pipelined with II = 1 and depth 5
//! and has no data hazards (the quire is a same-stage accumulator), so
//! functional results are computed combinationally at issue while the
//! cycle counter advances exactly as the RTL would: `cycles = issues +
//! (depth - 1)` per drain. Per-stage activity counters feed the energy
//! model in [`crate::cost`].
//!
//! Stage 1 is implemented *structurally* through the SIMD submodules
//! (complementor / LOD / shifter), not by calling the golden
//! `posit::decode` — the unit tests assert the two agree exhaustively,
//! which is exactly the RTL-vs-SoftPosit check of §III.

use super::{booth, complementor, lod, shifter, Mode};
use crate::posit::{PositClass, Quire};

/// Per-stage switching-activity counters (feed the ASIC energy model).
#[derive(Debug, Clone, Default)]
pub struct StageActivity {
    /// Cycles the engine has been stepped (including drain latency).
    pub cycles: u64,
    /// Lane-operand unpacks performed in Stage 1.
    pub unpacks: u64,
    /// Lane multiplies in Stage 2.
    pub mults: u64,
    /// Booth partial products generated in Stage 2.
    pub partial_products: u64,
    /// Quire adds in Stage 3 (excludes bypassed/zero products).
    pub quire_adds: u64,
    /// Stage 3 issues gated off by the enable signal (bypass).
    pub bypassed: u64,
    /// Stage 4/5 normalize+round events (accumulator drains).
    pub rounds: u64,
}

impl StageActivity {
    /// Effective MAC operations performed (lane-level).
    pub fn macs(&self) -> u64 {
        self.mults
    }
}

/// Decoded lane fields produced by the structural Stage 1.
#[derive(Debug, Clone, Copy)]
struct LaneFields {
    class: PositClass,
    sign: bool,
    scale: i32,
    /// Significand with implicit leading one, `fbits + 1` bits.
    sig: u64,
    fbits: u32,
}

/// Structural Stage 1 for one packed operand word: sign strip via the
/// mode-aware complementor, regime decode via the SIMD LOD, field
/// alignment via the barrel shifter.
///
/// Allocation-free (fixed 4-slot arrays; unused lanes report Zero) —
/// this is the simulator's hottest function (see EXPERIMENTS.md §Perf).
fn unpack_word(word: u32, mode: Mode) -> [LaneFields; 4] {
    let fmt = mode.format();
    let n = fmt.nbits;
    let lanes = mode.lanes();

    // sign bits and special-case detection per lane
    let mut signs = [false; 4];
    for (i, s) in signs.iter_mut().enumerate().take(lanes) {
        *s = (super::lane_extract(word, mode, i) >> (n - 1)) & 1 == 1;
    }

    // Mode-aware two's complement of negative lanes (Fig. 2b).
    let mag_word =
        complementor::simd_complement(word, &signs[..lanes], mode);

    // Regime decode: LOD over (body XOR r0-extended) — a run of r0 bits
    // ends where a bit differs, which is the leading one of t.
    let mut t_word = 0u32;
    let mut r0s = [false; 4];
    for i in 0..lanes {
        let mag = super::lane_extract(mag_word, mode, i);
        let body = mag & ((1u64 << (n - 1)) - 1);
        let r0 = (mag >> (n - 2)) & 1 == 1;
        r0s[i] = r0;
        let t = if r0 { !body & ((1u64 << (n - 1)) - 1) } else { body };
        t_word = super::lane_insert(t_word, mode, i, t);
    }
    let lods = lod::simd_lod4(t_word, mode);

    // Field alignment: shift the body left so exponent+fraction sit at
    // the top, then slice (Fig. 2c usage).
    let mut shift_amts = [0u32; 4];
    let mut ks = [0i32; 4];
    let mut term = [-1i32; 4];
    for i in 0..lanes {
        let j = if lods[i].valid { lods[i].pos as i32 } else { -1 };
        let run = (n as i32 - 2) - j;
        ks[i] = if r0s[i] {
            if lods[i].valid { run - 1 } else { n as i32 - 2 }
        } else {
            // body == 0 can only be the zero/NaR words, handled below
            -run
        };
        term[i] = if r0s[i] && !lods[i].valid { -1 } else { j };
        // left-shift amount to bring the terminator out: n-1 - j bits
        shift_amts[i] = (n as i32 - 1 - term[i].max(0)) as u32;
    }
    let aligned = shifter::simd_shift(
        t_align_input(mag_word, mode), &shift_amts[..lanes],
        shifter::Dir::Left, mode);

    let zero_fields = LaneFields { class: PositClass::Zero, sign: false,
                                   scale: 0, sig: 0, fbits: 0 };
    let mut out = [zero_fields; 4];
    for i in 0..lanes {
        let raw = super::lane_extract(word, mode, i);
        if raw == 0 {
            continue;
        }
        if raw == fmt.nar() {
            out[i].class = PositClass::NaR;
            continue;
        }
        let j = term[i].max(0) as u32;
        let have = fmt.es.min(j);
        // `aligned` holds the low j bits of the body shifted to the
        // top of the lane: bits [n-1-j .. n-2] hold exp+frac.
        let lane_aligned = super::lane_extract(aligned, mode, i);
        let field = lane_aligned >> (n - 1 - j).min(63);
        let field = field & ((1u64 << j) - 1);
        let exp = ((field >> (j - have)) << (fmt.es - have)) as u32;
        let fbits = j - have;
        let frac = field & ((1u64 << fbits) - 1);
        let scale = ks[i] * fmt.useed_pow() + exp as i32;
        out[i] = LaneFields {
            class: PositClass::Normal,
            sign: signs[i],
            scale,
            sig: (1u64 << fbits) | frac,
            fbits,
        };
    }
    out
}

/// The body bits enter the shifter masked to n-1 bits (sign removed).
fn t_align_input(mag_word: u32, mode: Mode) -> u32 {
    let n = mode.lane_bits();
    let mut out = 0u32;
    for i in 0..mode.lanes() {
        let mag = super::lane_extract(mag_word, mode, i);
        let body = mag & ((1u64 << (n - 1)) - 1);
        out = super::lane_insert(out, mode, i, body);
    }
    out
}

/// The SPADE MAC engine: one PE datapath in a chosen MODE.
///
/// Issue packed operand pairs with [`MacEngine::mac`]; drain the
/// per-lane quires to packed posit results with [`MacEngine::read`].
#[derive(Debug, Clone)]
pub struct MacEngine {
    mode: Mode,
    quires: Vec<Quire>,
    activity: StageActivity,
}

/// Pipeline depth (five stages -> 4 cycles of drain latency).
pub const PIPE_DEPTH: u64 = 5;

impl MacEngine {
    /// New engine in `mode` with cleared accumulators.
    pub fn new(mode: Mode) -> Self {
        Self {
            mode,
            quires: (0..mode.lanes()).map(|_| Quire::new(mode.format()))
                .collect(),
            activity: StageActivity::default(),
        }
    }

    /// Current mode.
    pub fn mode(&self) -> Mode {
        self.mode
    }

    /// Switch MODE: drains (flushes) the pipeline and clears the quires,
    /// exactly as the RTL must between precision regions.
    pub fn set_mode(&mut self, mode: Mode) {
        self.activity.cycles += PIPE_DEPTH - 1; // drain
        self.mode = mode;
        self.quires = (0..mode.lanes()).map(|_| Quire::new(mode.format()))
            .collect();
    }

    /// Activity counters.
    pub fn activity(&self) -> &StageActivity {
        &self.activity
    }

    /// Issue one packed MAC: per active lane, `acc[i] += a[i] * b[i]`.
    ///
    /// `enable = false` models the Stage 3 bypass gate: the operands
    /// flow through Stages 1-2 but the quire is not touched.
    pub fn mac(&mut self, a: u32, b: u32, enable: bool) {
        self.activity.cycles += 1;
        let fa = unpack_word(a, self.mode);
        let fb = unpack_word(b, self.mode);
        self.activity.unpacks += 2 * self.mode.lanes() as u64;

        // Stage 2: partitioned Booth multiply of the significands.
        let sig_a = [fa[0].sig, fa[1].sig, fa[2].sig, fa[3].sig];
        let sig_b = [fb[0].sig, fb[1].sig, fb[2].sig, fb[3].sig];
        let (products, pps) =
            booth::simd_booth_mul4(&sig_a, &sig_b, self.mode);
        self.activity.mults += self.mode.lanes() as u64;
        self.activity.partial_products += pps as u64;

        if !enable {
            self.activity.bypassed += self.mode.lanes() as u64;
            return;
        }

        // Stage 3: exact quire accumulation.
        for i in 0..self.mode.lanes() {
            match (fa[i].class, fb[i].class) {
                (PositClass::NaR, _) | (_, PositClass::NaR) => {
                    self.quires[i].set_nar();
                }
                (PositClass::Zero, _) | (_, PositClass::Zero) => {}
                _ => {
                    let weight = fa[i].scale + fb[i].scale
                        - (fa[i].fbits + fb[i].fbits) as i32;
                    self.quires[i].mac_raw(
                        products[i],
                        weight,
                        fa[i].sign ^ fb[i].sign,
                    );
                    self.activity.quire_adds += 1;
                }
            }
        }
    }

    /// Drain Stages 4-5: normalize + round each lane's quire into a
    /// packed posit word. Accounts the pipeline drain latency.
    pub fn read(&mut self) -> u32 {
        self.activity.cycles += PIPE_DEPTH - 1;
        self.activity.rounds += self.mode.lanes() as u64;
        let lanes: Vec<u64> =
            self.quires.iter().map(|q| q.to_posit()).collect();
        super::pack_lanes(&lanes, self.mode)
    }

    /// Clear the accumulators without draining the pipe (new tile).
    pub fn clear(&mut self) {
        for q in &mut self.quires {
            q.clear();
        }
    }

    /// Convenience: full dot product of packed operand streams, returning
    /// the packed posit result.
    pub fn dot(&mut self, a: &[u32], b: &[u32]) -> u32 {
        assert_eq!(a.len(), b.len());
        self.clear();
        for (&x, &y) in a.iter().zip(b) {
            self.mac(x, y, true);
        }
        self.read()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::{decode, from_f64, to_f64};
    use crate::util::SplitMix64;

    /// Structural Stage 1 must agree with the golden decoder — the
    /// module-level RTL-vs-SoftPosit check, exhaustive for P8.
    #[test]
    fn unpack_matches_decode_exhaustive_p8() {
        for w in 0u32..=0xFFFF_FFFF_u32.min(0xFFFF) {
            // pack the same 8-bit word in all four lanes plus a varying
            // neighbour to catch cross-lane leakage
            let a = (w & 0xFF) as u32;
            let word = a | (a.wrapping_add(1) & 0xFF) << 8
                | (a.wrapping_add(77) & 0xFF) << 16 | (a ^ 0x5A) << 24;
            let fields = unpack_word(word, Mode::P8x4);
            for (i, f) in fields.iter().enumerate() {
                let lane = super::super::lane_extract(word, Mode::P8x4, i);
                let d = decode(lane, Mode::P8x4.format());
                assert_eq!(f.class, d.class, "lane word {lane:#x}");
                if d.class == PositClass::Normal {
                    assert_eq!(f.sign, d.sign, "word {lane:#x}");
                    assert_eq!(f.scale, d.scale, "word {lane:#x}");
                    assert_eq!(f.sig, d.significand(), "word {lane:#x}");
                    assert_eq!(f.fbits, d.fbits, "word {lane:#x}");
                }
            }
        }
    }

    #[test]
    fn unpack_matches_decode_p16_p32_random() {
        let mut rng = SplitMix64::new(8);
        for _ in 0..200_000 {
            let word = rng.next_u64() as u32;
            for mode in [Mode::P16x2, Mode::P32x1] {
                let fields = unpack_word(word, mode);
                for (i, f) in fields.iter().take(mode.lanes())
                    .enumerate()
                {
                    let lane = super::super::lane_extract(word, mode, i);
                    let d = decode(lane, mode.format());
                    assert_eq!(f.class, d.class);
                    if d.class == PositClass::Normal {
                        assert_eq!((f.sign, f.scale, f.sig, f.fbits),
                                   (d.sign, d.scale, d.significand(),
                                    d.fbits),
                                   "mode {mode:?} word {lane:#x}");
                    }
                }
            }
        }
    }

    #[test]
    fn single_mac_equals_golden_mul() {
        let mut rng = SplitMix64::new(9);
        for mode in Mode::ALL {
            let fmt = mode.format();
            for _ in 0..20_000 {
                let a: Vec<u64> = (0..mode.lanes())
                    .map(|_| rng.next_u64() & fmt.mask()).collect();
                let b: Vec<u64> = (0..mode.lanes())
                    .map(|_| rng.next_u64() & fmt.mask()).collect();
                let mut eng = MacEngine::new(mode);
                eng.mac(super::super::pack_lanes(&a, mode),
                        super::super::pack_lanes(&b, mode), true);
                let out = eng.read();
                for i in 0..mode.lanes() {
                    let want = crate::posit::p_mul(a[i], b[i], fmt);
                    let got = super::super::lane_extract(out, mode, i);
                    assert_eq!(got, want,
                               "mode {mode:?} {:#x}*{:#x}", a[i], b[i]);
                }
            }
        }
    }

    #[test]
    fn bypass_gate_blocks_accumulation() {
        let mode = Mode::P16x2;
        let one = from_f64(1.0, mode.format());
        let word = super::super::pack_lanes(&[one, one], mode);
        let mut eng = MacEngine::new(mode);
        eng.mac(word, word, false); // bypassed
        eng.mac(word, word, true);
        let out = eng.read();
        for i in 0..2 {
            assert_eq!(to_f64(super::super::lane_extract(out, mode, i),
                              mode.format()), 1.0);
        }
        assert_eq!(eng.activity().bypassed, 2);
    }

    #[test]
    fn cycle_accounting() {
        let mut eng = MacEngine::new(Mode::P8x4);
        for _ in 0..10 {
            eng.mac(0, 0, true);
        }
        let _ = eng.read();
        assert_eq!(eng.activity().cycles, 10 + PIPE_DEPTH - 1);
        assert_eq!(eng.activity().macs(), 40); // 4 lanes x 10 issues
    }

    #[test]
    fn throughput_scales_with_mode() {
        // The headline claim: 4x / 2x / 1x MACs per cycle.
        for (mode, per_cycle) in
            [(Mode::P8x4, 4), (Mode::P16x2, 2), (Mode::P32x1, 1)]
        {
            let mut eng = MacEngine::new(mode);
            for _ in 0..100 {
                eng.mac(0x3F3F_3F3F, 0x4242_4242, true);
            }
            assert_eq!(eng.activity().macs(), 100 * per_cycle);
        }
    }

    #[test]
    fn dot_matches_quire_golden() {
        let mut rng = SplitMix64::new(10);
        for mode in Mode::ALL {
            let fmt = mode.format();
            for _ in 0..500 {
                let len = 16;
                let mut lanes_a = vec![Vec::new(); mode.lanes()];
                let mut lanes_b = vec![Vec::new(); mode.lanes()];
                let mut packed_a = Vec::new();
                let mut packed_b = Vec::new();
                for _ in 0..len {
                    let a: Vec<u64> = (0..mode.lanes())
                        .map(|_| from_f64(rng.wide(-4, 4), fmt)).collect();
                    let b: Vec<u64> = (0..mode.lanes())
                        .map(|_| from_f64(rng.wide(-4, 4), fmt)).collect();
                    for i in 0..mode.lanes() {
                        lanes_a[i].push(a[i]);
                        lanes_b[i].push(b[i]);
                    }
                    packed_a.push(super::super::pack_lanes(&a, mode));
                    packed_b.push(super::super::pack_lanes(&b, mode));
                }
                let mut eng = MacEngine::new(mode);
                let out = eng.dot(&packed_a, &packed_b);
                for i in 0..mode.lanes() {
                    let mut q = Quire::new(fmt);
                    for k in 0..len {
                        q.mac(lanes_a[i][k], lanes_b[i][k]);
                    }
                    assert_eq!(super::super::lane_extract(out, mode, i),
                               q.to_posit(), "mode {mode:?} lane {i}");
                }
            }
        }
    }
}
