//! `spade` CLI — leader entrypoint for the reproduction stack.
//!
//! Subcommands:
//!   tables            print Tables I, II, III (model vs paper)
//!   eval              Fig. 4 accuracy sweep (--model, --limit, --modes,
//!                     --no-fused layer-wise pipeline cross-checked
//!                     bit-for-bit against the fused default)
//!   serve             run the precision-adaptive serving engine on
//!                     synthetic traffic (--requests, --rate-us,
//!                     --policy, --shards, --batch, --affinity
//!                     least-loaded|pinned-mode, --max-queue N
//!                     backpressure bound (0 = unbounded),
//!                     --deadline-ms N default request deadline,
//!                     --degrade-at F degrade-under-load threshold,
//!                     --faults SPEC deterministic fault injection
//!                     (e.g. shard_panic=0.01,delay_ms=5@0.02),
//!                     --autotune off|first-use|warmup,
//!                     --config PATH fleet config JSON (merge order
//!                     file < env < CLI), --stats-json PATH,
//!                     --stats-interval-ms N). Backend selection is
//!                     automatic: PJRT artifacts when present,
//!                     otherwise the sharded planar posit kernel on
//!                     trained or synthetic weights — serve always
//!                     comes up.
//!   trace             cycle-accurate systolic trace of a small GEMM
//!   info              artifact + model inventory
//!
//! All engine construction goes through `spade::api::EngineBuilder`:
//! `SPADE_*` environment variables are parsed once
//! (`EngineConfig::from_env`) and merged with the CLI flags here, at
//! the edge.

use std::time::Duration;

use anyhow::Result;

use spade::api::{EngineBuilder, RoutePolicy, ServeBackend,
                 ShardAffinity};
use spade::cost::{baselines, AsicReport, DesignKind, FpgaReport,
                  PipelineStage, TechNode};
use spade::data::{Dataset, TrafficGen};
use spade::engine::Mode;
use spade::nn::{Backend, Model, Precision, Tensor};
use spade::systolic::{ArrayConfig, SystolicGemm};
use spade::util::Args;

fn main() -> Result<()> {
    // The one environment parse of the process: SPADE_* knobs become
    // the kernel's installed defaults for every subcommand, so direct
    // kernel users (trace, tables) honor them too. serve/eval layer
    // richer builder configs on top of the same parse.
    spade::kernel::settings::install(
        spade::api::EngineConfig::from_env()?.kernel_config());
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("tables") => cmd_tables(),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("trace") => cmd_trace(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: spade <tables|eval|serve|trace|info> [options]\n\
                 see `cargo doc` or README.md for details"
            );
            Ok(())
        }
    }
}

fn cmd_tables() -> Result<()> {
    println!("== Table I: FPGA (Virtex-7) — model output ==");
    println!("{:<22} {:>6} {:>6} {:>9} {:>9}", "design", "LUT", "FF",
             "delay ns", "power mW");
    for r in FpgaReport::table1() {
        println!("{:<22} {:>6} {:>6} {:>9.2} {:>9.0}", r.kind.name(),
                 r.luts, r.ffs, r.delay_ns, r.power_mw);
    }
    for b in baselines::FPGA_BASELINES {
        println!("{:<22} {:>6} {:>6} {:>9.2} {:>9.0}  [paper-reported]",
                 b.cite, b.luts, b.ffs, b.delay_ns, b.power_mw);
    }
    let (lut_ovh, ff_ovh) = FpgaReport::simd_overhead_pct();
    println!("SIMD overhead vs standalone P32: {lut_ovh:.1}% LUT, \
              {ff_ovh:.1}% FF\n");

    println!("== Table II: ASIC 28 nm — model output ==");
    let r = AsicReport::for_design(DesignKind::SimdUnified, TechNode::N28);
    println!("This Work   0.9 V  {:.2} GHz  {:.3} mm2  {:.1} mW",
             r.freq_ghz, r.area_mm2(), r.power_mw);
    for b in baselines::ASIC_BASELINES {
        println!("{:<12}{:.2} V  {:.2} GHz  {:.3} mm2  {:.1} mW  \
                  [paper-reported]",
                 b.cite, b.supply_v, b.freq_ghz, b.area_mm2, b.power_mw);
    }

    println!("\n== Table III: stage-wise (28 nm) — model output ==");
    for s in PipelineStage::ALL {
        let (a, p) = r.stages[&s];
        println!("{:<28} {:>8.0} um2 {:>7.2} mW", s.name(), a, p);
    }
    println!("{:<28} {:>8.0} um2 {:>7.2} mW", "Total", r.area_um2,
             r.power_mw);
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model_name = args.get_or("model", "lenet5");
    let limit: usize = args.num_or("limit", 256);
    let modes = args.get_or("modes", "f32,p32,p16,p8");
    let no_fused = args.flag("no-fused");

    // Env-seeded engine: SPADE_KERNEL_* tuning applies to the sweep.
    // --no-fused selects the layer-wise escape hatch and cross-checks
    // each pass against the fused pipeline (the paths must be
    // bit-identical, so it is a verification mode, not a result mode).
    let engine = EngineBuilder::from_env()?
        .model(model_name.clone())
        .fused(!no_fused)
        .build()?;
    let model = Model::load(&model_name)?;
    let ds = Dataset::load_artifact(&model.spec.dataset, "test")?;
    let n = limit.min(ds.n);
    let (pix, labels) = ds.batch(0, n);
    let x = Tensor::from_vec(&[n, ds.h, ds.w, ds.c], pix);

    // One plan-cached session for the whole sweep: weight decode is
    // paid once per (layer, mode), not once per precision pass, and
    // the fused path additionally recycles interlayer plan buffers
    // across every forward below.
    let mut sess = engine.session(&model);
    let mut cross = no_fused.then(|| engine.session(&model).with_fused(true));
    println!("{model_name} on {} ({n} images){}", model.spec.dataset,
             if no_fused { "  [layer-wise + fused cross-check]" }
             else { "" });
    for mode in modes.split(',') {
        let prec = Precision::parse(mode)?;
        let backend = if prec == Precision::F32 { Backend::F32 }
                      else { Backend::Posit };
        let t0 = std::time::Instant::now();
        let (logits, stats) = sess.forward(&x, prec, backend)?;
        let acc = spade::nn::exec::accuracy(&logits, labels);
        let mut check = String::new();
        if let Some(fsess) = cross.as_mut() {
            let (flogits, _) = fsess.forward(&x, prec, backend)?;
            let same = logits
                .data
                .iter()
                .zip(&flogits.data)
                .all(|(a, b)| a.to_bits() == b.to_bits()
                         || (a.is_nan() && b.is_nan()));
            anyhow::ensure!(
                same,
                "{}: fused and layer-wise logits diverge — the \
                 epilogue exactness contract is broken",
                prec.name());
            check = "  fused==layer-wise OK".into();
        }
        println!("  {:<4} acc {:.4}  ({} MACs, {} cycles, {:.1} uJ) \
                  [{:.1}s wall]{check}",
                 prec.name(), acc, stats.macs, stats.cycles,
                 stats.energy_pj / 1e6, t0.elapsed().as_secs_f32());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let requests: usize = args.num_or("requests", 256);
    let rate_us: u64 = args.num_or("rate-us", 200);

    // Merge order: config file < SPADE_* environment < CLI flags —
    // each CLI flag only overrides when explicitly given, so a fleet
    // config file actually drives the deployment.
    let base = match args.options.get("config") {
        Some(path) => {
            let body = std::fs::read_to_string(path).map_err(|e| {
                anyhow::anyhow!("--config {path}: {e}")
            })?;
            spade::api::EngineConfig::from_json(&body)
                .map_err(|e| anyhow::anyhow!("--config {path}: {e}"))?
        }
        None => spade::api::EngineConfig::default(),
    };
    let mut builder = EngineBuilder::from_config(
        spade::api::EngineConfig::from_env_over(base)?);
    if let Some(m) = args.options.get("model") {
        builder = builder.model(m.clone());
    }
    if let Some(p) = args.options.get("policy") {
        builder = builder.policy(match p.as_str() {
            "accuracy" => RoutePolicy::AccuracyFirst,
            "balanced" => RoutePolicy::Balanced,
            _ => RoutePolicy::EnergyFirst,
        });
    }
    if args.options.contains_key("shards") {
        builder = builder.shards(args.num_or("shards", 0));
    }
    if let Some(a) = args.options.get("affinity") {
        builder = builder.affinity(match a.as_str() {
            "pinned-mode" => ShardAffinity::PinnedMode,
            _ => ShardAffinity::LeastLoaded,
        });
    }
    if args.options.contains_key("batch") {
        builder =
            builder.batch(args.num_or("batch", 32usize).max(1));
    }
    if args.options.contains_key("max-queue") {
        builder = builder.max_queue(args.num_or("max-queue", 0));
    }
    if args.options.contains_key("deadline-ms") {
        builder = builder
            .default_deadline_ms(args.num_or("deadline-ms", 0u64));
    }
    if let Some(f) = args.options.get("degrade-at") {
        let v = f.trim().parse::<f64>().map_err(|_| {
            anyhow::anyhow!("--degrade-at {f}: not a number")
        })?;
        builder = builder.degrade_at(v);
    }
    if let Some(spec) = args.options.get("faults") {
        builder = builder.faults(
            spade::api::FaultPlan::parse(spec)
                .map_err(anyhow::Error::msg)?);
    }
    if let Some(mode) = args.options.get("autotune") {
        builder = builder.autotune(
            spade::api::EngineConfig::parse_autotune(mode)?);
    }
    let stats_json = args.options.get("stats-json").cloned();
    if let Some(path) = &stats_json {
        builder = builder.stats_json(path).stats_interval(
            Duration::from_millis(
                args.num_or("stats-interval-ms", 1000u64).max(1)));
    }
    let engine = builder.build()?;

    // Warm up before traffic: pre-tune every GEMM regime serving can
    // dispatch and pre-build the kernel tables, so no request ever
    // pays a probe. Full batches land in the square/deep-k regimes;
    // under-filled batches (slow traffic flushing early) are skinny —
    // cover all three classes explicitly.
    if engine.config().autotune != spade::api::AutotuneMode::Off {
        let b = engine.config().batch.max(16);
        let probes = engine.warm_up(&[
            (b, 256, 64),  // square: filled batches
            (b, 2048, 64), // deep-k: deep reductions
            (4, 256, 64),  // skinny: under-filled batches
        ])?;
        println!("warm-up: {probes} autotune probe(s)");
    }

    let handle = engine.serve()?;
    match handle.backend() {
        Some(ServeBackend::Pjrt) => {
            println!("engine: PJRT artifacts")
        }
        Some(ServeBackend::PlanarTrained) => {
            println!("engine: sharded planar kernel (trained weights; \
                      no PJRT manifest)")
        }
        Some(ServeBackend::PlanarSynthetic) | None => {
            println!("engine: sharded planar kernel (synthetic model; \
                      no artifacts on disk)")
        }
    }
    let mut gen = TrafficGen::new(7, rate_us, handle.input_len());

    println!("serving {requests} requests (mean gap {rate_us} us, \
              policy {:?}, batch {}) ...",
             engine.config().effective_policy(),
             engine.config().batch);
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    let mut rejected = 0usize;
    for r in gen.burst(requests) {
        match handle.submit(spade::coordinator::InferenceRequest {
            id: r.id,
            input: r.input,
            mode: r.mode,
            deadline_ms: None,
        }) {
            Ok(rx) => rxs.push(rx),
            // Backpressure (--max-queue): shed the request and keep
            // going — exactly what a fleet edge would do.
            Err(_) => rejected += 1,
        }
    }
    let mut failed = 0usize;
    for rx in rxs {
        match rx.recv() {
            Ok(Ok(_)) => {}
            Ok(Err(_)) | Err(_) => failed += 1,
        }
    }
    let wall = t0.elapsed();
    let m = handle.shutdown();
    println!("{}", m.summary());
    // try_global: reporting must never create the pool (a PJRT serve
    // may legitimately never touch the planar kernel).
    if let Some(p) = spade::kernel::pool::try_global() {
        let respawned = p.workers_respawned();
        if respawned > 0 {
            println!("kernel pool: {respawned} worker respawn(s) \
                      (escaped panics; see --stats-json \
                      pool_respawned)");
        }
    }
    if rejected > 0 {
        println!("rejected at submit (overload): {rejected}");
    }
    if failed > 0 {
        println!("failed typed (deadline/shard): {failed}");
    }
    println!("throughput: {:.0} req/s",
             requests as f64 / wall.as_secs_f64());
    if let Some(path) = stats_json {
        println!("stats dump: {path}");
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let m: usize = args.num_or("m", 8);
    let k: usize = args.num_or("k", 16);
    let n: usize = args.num_or("n", 8);
    for mode in Mode::ALL {
        let cfg = ArrayConfig { rows: 4, cols: 2, mode };
        let g = SystolicGemm::new(cfg);
        let a = vec![0.5; m * k];
        let b = vec![0.25; k * n];
        let (_, stats) = g.run_cycle_accurate(&a, &b, m, k, n);
        println!("{mode:?}: {} cycles, {} MACs ({:.2} MACs/cycle), \
                  {:.1} nJ",
                 stats.cycles, stats.macs, stats.macs_per_cycle(),
                 stats.total_energy_pj() / 1e3);
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = spade::artifacts_dir();
    println!("artifacts: {}", dir.display());
    if let Ok(rt) = spade::runtime::Runtime::new() {
        println!("{rt:?}");
        for a in rt.artifacts() {
            println!("  {a}");
        }
    } else {
        println!("  (no manifest — run `make artifacts`)");
    }
    for name in ["mlp", "lenet5", "cnn5", "alexnet_mini", "vgg16_mini",
                 "alpha_cnn"] {
        match Model::load(name) {
            Ok(m) => {
                let macs: u64 = m.spec.layer_macs().iter().sum();
                println!("model {name:<14} {} layers, {} MAC layers, \
                          {macs} MACs/image",
                         m.spec.layers.len(), m.spec.mac_layers());
            }
            Err(e) => println!("model {name:<14} unavailable: {e}"),
        }
    }
    Ok(())
}
