//! Small self-contained utilities: deterministic RNG, a mini
//! property-testing harness, and a no-dependency CLI argument parser.
//!
//! The build environment is offline (no crates.io), so the usual
//! `rand`/`proptest`/`clap` stack is replaced by these — deliberately tiny
//! and fully tested — equivalents.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

pub use cli::Args;
pub use json::Json;
pub use prop::Prop;
pub use rng::SplitMix64;
