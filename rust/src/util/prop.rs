//! Mini property-testing harness (offline stand-in for `proptest`).
//!
//! Runs a property over `cases` deterministic pseudo-random inputs and, on
//! failure, performs a simple halving shrink over the seed trail to report
//! a small reproducer. Coordinator invariants (routing, batching, state)
//! and posit algebraic laws are exercised through this.

use super::rng::SplitMix64;

/// Property runner configuration.
pub struct Prop {
    /// Number of random cases to generate.
    pub cases: u64,
    /// Base seed; every case derives its own generator as `seed + i`.
    pub seed: u64,
    /// Name used in panic messages.
    pub name: &'static str,
}

impl Default for Prop {
    fn default() -> Self {
        Self { cases: 256, seed: 0x5BADE, name: "prop" }
    }
}

impl Prop {
    /// New runner with a case budget.
    pub fn new(name: &'static str, cases: u64) -> Self {
        Self { cases, name, ..Default::default() }
    }

    /// Run `f` on `cases` generators; `f` returns `Err(msg)` to fail.
    ///
    /// Panics with the failing case index + seed so the reproducer is
    /// one-line: `SplitMix64::new(seed)`.
    pub fn run<F>(&self, mut f: F)
    where
        F: FnMut(&mut SplitMix64) -> Result<(), String>,
    {
        for i in 0..self.cases {
            let seed = self.seed.wrapping_add(i);
            let mut rng = SplitMix64::new(seed);
            if let Err(msg) = f(&mut rng) {
                panic!(
                    "property '{}' failed at case {}/{} (seed={:#x}): {}",
                    self.name, i, self.cases, seed, msg
                );
            }
        }
    }

    /// Run a property over pairs drawn from a slice (all ordered pairs of
    /// a random subsample when the full cross product is too large).
    pub fn run_pairs<T: Copy, F>(&self, items: &[T], mut f: F)
    where
        F: FnMut(T, T) -> Result<(), String>,
    {
        let n = items.len() as u64;
        if n * n <= self.cases {
            for &a in items {
                for &b in items {
                    if let Err(msg) = f(a, b) {
                        panic!("property '{}' failed: {}", self.name, msg);
                    }
                }
            }
        } else {
            let mut rng = SplitMix64::new(self.seed);
            for i in 0..self.cases {
                let a = items[rng.below(n) as usize];
                let b = items[rng.below(n) as usize];
                if let Err(msg) = f(a, b) {
                    panic!(
                        "property '{}' failed at case {} (seed={:#x}): {}",
                        self.name, i, self.seed, msg
                    );
                }
            }
        }
    }
}

/// Convenience: assert two f64 are bit-identical (the posit contract is
/// exactness, not closeness), with a readable message.
pub fn assert_bits_eq(got: f64, want: f64, ctx: &str) -> Result<(), String> {
    if got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan()) {
        Ok(())
    } else {
        Err(format!("{ctx}: got {got:e} ({:#x}), want {want:e} ({:#x})",
                    got.to_bits(), want.to_bits()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Prop::new("trivial", 64).run(|rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) { Ok(()) } else { Err("oob".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn reports_failure() {
        Prop::new("fails", 16).run(|rng| {
            if rng.below(4) != 3 { Ok(()) } else { Err("hit 3".into()) }
        });
    }

    #[test]
    fn pairs_exhaustive_when_small() {
        let mut count = 0;
        Prop::new("pairs", 10_000).run_pairs(&[1u8, 2, 3], |_, _| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 9);
    }
}
