//! SplitMix64 — the canonical small deterministic PRNG (Steele et al.,
//! "Fast Splittable Pseudorandom Number Generators", OOPSLA 2014).
//!
//! Used everywhere randomness is needed: property tests, workload
//! generators, synthetic request traffic. Deterministic given the seed,
//! so every test and benchmark is exactly reproducible.

/// SplitMix64 PRNG state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. `bound` must be nonzero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        // Lemire's multiply-shift rejection-free approximation is fine for
        // test workloads; bias is < 2^-53 for the bounds we use.
        ((self.next_u64() >> 11) as u128 * bound as u128 >> 53) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box-Muller (two uniforms per call; simple and
    /// deterministic — throughput is irrelevant at test scale).
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Random value with wide dynamic range: `normal * 2^[lo, hi)` —
    /// the shape posit test vectors need (sign + regime sweep).
    pub fn wide(&mut self, lo: i32, hi: i32) -> f64 {
        let e = lo + self.below((hi - lo) as u64) as i32;
        self.normal() * (e as f64).exp2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_vector() {
        // First outputs for seed 0 (reference values from the SplitMix64
        // paper's reference implementation).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(9);
        let mut acc = 0.0;
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        assert!((acc / 1000.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(11);
        let n = 20000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }
}
