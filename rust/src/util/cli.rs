//! Zero-dependency CLI argument parsing (offline stand-in for `clap`).
//!
//! Grammar: `program <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// `--key value` pairs and bare `--flag`s (value `"true"`).
    pub options: HashMap<String, String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (testable) — see [`Args::from_env`].
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Self {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                let is_flag = it
                    .peek()
                    .map(|n| n.starts_with("--"))
                    .unwrap_or(true);
                let val = if is_flag {
                    "true".to_string()
                } else {
                    it.next().unwrap()
                };
                out.options.insert(key.to_string(), val);
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv\[0\]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Option value with a default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.into())
    }

    /// Parsed numeric option with a default; panics with a clear message
    /// on malformed input (CLI surface, not library surface).
    pub fn num_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(key) {
            None => default,
            Some(s) => s.parse().unwrap_or_else(|e| {
                panic!("invalid value for --{key}: {s:?} ({e})")
            }),
        }
    }

    /// True if `--key` was passed (as flag or with any value but "false").
    pub fn flag(&self, key: &str) -> bool {
        self.options.get(key).map(|v| v != "false").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_options_positional() {
        // NB: a bare flag directly followed by a positional is ambiguous
        // ("--verbose input.bin" reads as --verbose=input.bin); the CLI
        // convention here is flags go last or take explicit values.
        let a = Args::parse(toks("serve --batch 8 input.bin --verbose"));
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get_or("batch", "1"), "8");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["input.bin"]);
    }

    #[test]
    fn num_or_defaults() {
        let a = Args::parse(toks("run --n 32"));
        assert_eq!(a.num_or("n", 0u32), 32);
        assert_eq!(a.num_or("m", 7u32), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(toks("x --fast"));
        assert!(a.flag("fast"));
    }
}
