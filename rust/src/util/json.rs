//! Minimal JSON parser (offline stand-in for `serde_json`).
//!
//! Parses the artifact metadata this crate consumes: model layer specs,
//! `manifest.json`, and `metrics.json`. Full JSON grammar (objects,
//! arrays, strings with escapes, numbers, bools, null); no serialization
//! beyond what the benches need.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (f64 carrier).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (ordered map for deterministic iteration).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric content.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric content as usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Bool content.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array content.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object content.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write!(f, "{s:?}"),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{k:?}:{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E'
                                                    | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek()
                        .ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u hex")?;
                            self.i += 4;
                            out.push(char::from_u32(cp)
                                .unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}",
                                                e as char)),
                    }
                }
                Some(c) => {
                    // copy raw UTF-8 bytes through
                    let len = utf8_len(c);
                    out.push_str(
                        std::str::from_utf8(&self.b[self.i..self.i + len])
                            .map_err(|_| "bad utf8")?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_model_spec_shape() {
        let src = r#"{"name": "mlp", "input": [28, 28, 1],
                      "layers": [{"kind": "flatten"},
                                 {"kind": "dense", "out": 128,
                                  "relu": true}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("mlp"));
        let input = j.get("input").unwrap().as_arr().unwrap();
        assert_eq!(input[0].as_usize(), Some(28));
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers[1].get("relu").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_numbers() {
        let j = Json::parse("[-1.5e3, 0, 42, 0.125]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1500.0));
        assert_eq!(a[3].as_f64(), Some(0.125));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = Json::parse(r#""a\n\t\"A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{bad}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(),
                   Json::Obj(BTreeMap::new()));
    }
}
