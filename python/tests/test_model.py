"""L2 model-zoo tests: shapes, spec walking, posit-vs-train-forward parity."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

SMALL = ["mlp", "lenet5", "alpha_cnn"]


@pytest.mark.parametrize("name", list(model.ZOO))
def test_shapes_walk(name):
    walked = model.shapes_through(name)
    assert walked[-1][2] == (model.ZOO[name]["classes"],)


@pytest.mark.parametrize("name", list(model.ZOO))
def test_init_params_match_spec(name):
    params = model.init_params(name)
    for i, (layer, ishape, oshape) in enumerate(model.shapes_through(name)):
        if layer["kind"] == "conv":
            assert params[f"layer{i}/w"].shape == \
                (layer["k"], layer["k"], ishape[2], layer["out"])
        elif layer["kind"] == "dense":
            assert params[f"layer{i}/w"].shape == (ishape[0], layer["out"])


@pytest.mark.parametrize("name", SMALL)
def test_forward_train_shape(name):
    spec = model.ZOO[name]
    params = model.init_params(name)
    x = jnp.zeros([4] + spec["input"], jnp.float32)
    y = model.forward_train(params, name, x)
    assert y.shape == (4, spec["classes"])


@pytest.mark.parametrize("name", SMALL)
def test_forward_posit_f32_matches_train(name):
    """f32 'posit' mode = no quantization -> must match the lax graph."""
    spec = model.ZOO[name]
    params = model.init_params(name, seed=3)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=[2] + spec["input"]).astype(np.float32))
    yt = np.array(model.forward_train(params, name, x))
    yp = np.array(model.forward_posit(params, name, x, "f32"))
    np.testing.assert_allclose(yp, yt, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("mode", ["p8", "p16", "p32"])
def test_forward_posit_runs_all_modes(mode):
    params = model.init_params("mlp", seed=1)
    x = jnp.zeros([2, 28, 28, 1], jnp.float32)
    y = model.forward_posit(params, "mlp", x, mode)
    assert y.shape == (2, 10)
    assert np.all(np.isfinite(np.array(y)))


def test_posit_close_to_f32_forward():
    """Fig. 4 premise in miniature: P16/P32 logits track f32 logits."""
    params = model.init_params("mlp", seed=2)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(0, 1, size=[8, 28, 28, 1])
                    .astype(np.float32))
    y32 = np.array(model.forward_posit(params, "mlp", x, "f32"))
    for mode, tol in [("p32", 1e-5), ("p16", 5e-2)]:
        ym = np.array(model.forward_posit(params, "mlp", x, mode))
        rel = np.max(np.abs(ym - y32) / (np.abs(y32) + 1.0))
        assert rel < tol, (mode, rel)


def test_spec_json_round_trip():
    import json
    for name in model.ZOO:
        spec = json.loads(model.spec_json(name))
        assert spec["name"] == name
        assert spec["layers"] == model.ZOO[name]["layers"]
        assert spec["dataset"] == model.MODEL_DATASET[name]
