"""Synthetic dataset generator tests: determinism, format, learnability."""

import os
import tempfile

import numpy as np
import pytest

from compile import datasets


def test_glyph_deterministic():
    a_imgs, a_lab = datasets.make_glyph_dataset("0123456789", 64, seed=5)
    b_imgs, b_lab = datasets.make_glyph_dataset("0123456789", 64, seed=5)
    np.testing.assert_array_equal(a_imgs, b_imgs)
    np.testing.assert_array_equal(a_lab, b_lab)


def test_glyph_shapes_and_range():
    imgs, lab = datasets.make_glyph_dataset("ABC", 32, seed=1)
    assert imgs.shape == (32, 28, 28, 1)
    assert imgs.min() >= 0.0 and imgs.max() <= 1.0
    assert set(np.unique(lab)) <= {0, 1, 2}


def test_texture_class_signature_stable():
    """Same-class instances are on average more correlated than
    cross-class pairs (single pairs can decorrelate through the random
    phases, so compare means over many instances)."""
    def mean_corr(cls_a, cls_b, n=12):
        cs = []
        for i in range(n):
            a = datasets._render_texture(cls_a, 10,
                                         np.random.default_rng(100 + i))
            b = datasets._render_texture(cls_b, 10,
                                         np.random.default_rng(500 + i))
            cs.append(abs(np.corrcoef(a.ravel(), b.ravel())[0, 1]))
        return np.mean(cs)

    same = np.mean([mean_corr(c, c) for c in [1, 4, 8]])
    diff = np.mean([mean_corr(a, b) for a, b in [(1, 4), (4, 8), (8, 1)]])
    assert same > diff, (same, diff)


def test_spdd_round_trip():
    imgs, lab = datasets.make_glyph_dataset("01", 16, seed=2)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.bin")
        datasets.write_spdd(p, imgs, lab, 2)
        data, labels, ncls = datasets.read_spdd(p)
        np.testing.assert_array_equal(data, imgs)
        np.testing.assert_array_equal(labels, lab)
        assert ncls == 2


def test_linear_probe_learnable():
    """A linear probe separates the glyph classes — the synthetic task is
    learnable, which is all Fig. 4 needs."""
    imgs, lab = datasets.make_glyph_dataset("0123456789", 400, seed=9)
    X = imgs.reshape(400, -1)
    # one-vs-all least squares
    Y = np.eye(10)[lab]
    W = np.linalg.lstsq(X, Y, rcond=None)[0]
    acc = np.mean((X @ W).argmax(1) == lab)
    assert acc > 0.8, acc
