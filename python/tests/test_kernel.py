"""L1 Pallas kernel vs pure-jnp oracle — the core correctness signal.

hypothesis sweeps shapes and formats; assert_allclose against ref.py.
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import posit_matmul as K
from compile.kernels import ref as R

MODES = ["p8", "p16", "p32", "f32"]


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=shape) * scale).astype(np.float32)


@pytest.mark.parametrize("mode", MODES)
def test_matmul_matches_ref_basic(mode):
    x, w = rand((17, 40), 0), rand((40, 23), 1)
    got = np.array(K.posit_matmul(x, w, mode=mode))
    want = np.array(R.matmul_ref(x, w, mode))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-30)


@pytest.mark.parametrize("mode", ["p8", "p16"])
def test_matmul_bitexact_low_precision(mode):
    """For P8/P16 the f32 output carries the posit value exactly."""
    x, w = rand((8, 64), 2), rand((64, 8), 3)
    got = np.array(K.posit_matmul(x, w, mode=mode))
    want = np.array(R.matmul_ref(x, w, mode)).astype(np.float32)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 70), k=st.integers(1, 90), n=st.integers(1, 70),
       mode=st.sampled_from(MODES), seed=st.integers(0, 2**31),
       logscale=st.integers(-6, 6))
def test_matmul_matches_ref_shapes(m, k, n, mode, seed, logscale):
    x = rand((m, k), seed, 2.0 ** logscale)
    w = rand((k, n), seed + 1, 2.0 ** (-logscale))
    got = np.array(K.posit_matmul(x, w, mode=mode))
    want = np.array(R.matmul_ref(x, w, mode))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-30)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 40), k=st.integers(1, 64), n=st.integers(1, 40),
       mode=st.sampled_from(MODES), relu=st.booleans(),
       seed=st.integers(0, 2**31))
def test_dense_matches_ref(m, k, n, mode, relu, seed):
    x, w = rand((m, k), seed), rand((k, n), seed + 1)
    b = rand((n,), seed + 2)
    got = np.array(K.posit_dense(x, w, b, mode=mode, relu=relu))
    want = np.array(R.dense_ref(x, w, b, mode, relu=relu))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-30)


@pytest.mark.parametrize("mode", ["p8", "p16", "p32"])
def test_quantize_op_matches_ref(mode):
    x = rand((512,), 7, 8.0)
    got = np.array(K.posit_quantize_op(x, mode=mode))
    want = np.array(R.quantize_ref(x, mode)).astype(np.float32)
    np.testing.assert_array_equal(got, want)


def test_quantization_monotone_precision():
    """P32 error <= P16 error <= P8 error on the same matmul (on average)."""
    x, w = rand((32, 64), 11), rand((64, 32), 12)
    exact = np.array(R.matmul_ref(x, w, "f32"))
    errs = {}
    for mode in ["p8", "p16", "p32"]:
        got = np.array(K.posit_matmul(x, w, mode=mode))
        errs[mode] = np.mean(np.abs(got - exact))
    assert errs["p32"] < errs["p16"] < errs["p8"]


def test_tile_shapes_mode_scaling():
    """DESIGN §5: P8 tiles cover 4x the area of P32 tiles (lane fusion)."""
    a8 = np.prod(K.MODE_TILES["p8"])
    a16 = np.prod(K.MODE_TILES["p16"])
    a32 = np.prod(K.MODE_TILES["p32"])
    assert a8 == 4 * a32 and a16 == 2 * a32
