"""Posit encode/decode/quantize unit + property tests (jnp golden twin)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import posit as P

FORMATS = [(8, 0), (16, 1), (32, 2)]


def decode_table(n, es):
    words = jnp.arange(1 << n, dtype=jnp.int64)
    return np.array(P.posit_decode(words, n, es))


@pytest.mark.parametrize("n,es", [(8, 0), (16, 1)])
def test_decode_monotone_and_symmetric(n, es):
    vals = decode_table(n, es)
    assert vals[0] == 0.0
    assert np.isnan(vals[1 << (n - 1)])
    pos = vals[1:(1 << (n - 1))]
    assert np.all(np.diff(pos) > 0), "positive ramp must be strictly monotone"
    neg = vals[(1 << (n - 1)) + 1:]
    np.testing.assert_array_equal(neg, -pos[::-1])


@pytest.mark.parametrize("n,es", [(8, 0), (16, 1)])
def test_exact_round_trip_exhaustive(n, es):
    vals = decode_table(n, es)
    enc = np.array(P.posit_encode(jnp.asarray(vals), n, es))
    words = np.arange(1 << n)
    ok = (enc == words) | np.isnan(vals)
    assert ok.all()


@pytest.mark.parametrize("n,es", FORMATS)
def test_extremes(n, es):
    useed_pow = (n - 2) * (1 << es)
    minpos = np.exp2(-useed_pow)
    maxpos = np.exp2(useed_pow)
    assert float(P.posit_decode(jnp.int64(1), n, es)) == minpos
    assert float(P.posit_decode(jnp.int64((1 << (n - 1)) - 1), n, es)) \
        == maxpos
    # no underflow to zero, no overflow to NaR
    assert float(P.posit_quantize(jnp.float64(minpos / 1000), n, es)) \
        == minpos
    assert float(P.posit_quantize(jnp.float64(maxpos * 1000), n, es)) \
        == maxpos


@pytest.mark.parametrize("n,es", FORMATS)
def test_specials(n, es):
    assert int(P.posit_encode(jnp.float64(0.0), n, es)) == 0
    nar = 1 << (n - 1)
    assert int(P.posit_encode(jnp.float64(np.nan), n, es)) == nar
    assert int(P.posit_encode(jnp.float64(np.inf), n, es)) == nar
    assert int(P.posit_encode(jnp.float64(-np.inf), n, es)) == nar
    assert np.isnan(float(P.posit_decode(jnp.int64(nar), n, es)))


@pytest.mark.parametrize("n,es", FORMATS)
def test_exact_small_integers(n, es):
    """Small integers are exactly representable in every SPADE format."""
    top = {8: 8, 16: 64, 32: 1024}[n]
    xs = np.arange(-top, top + 1, dtype=np.float64)
    q = np.array(P.posit_quantize(jnp.asarray(xs), n, es))
    np.testing.assert_array_equal(q, xs)


@settings(max_examples=300, deadline=None)
@given(st.floats(min_value=-1e20, max_value=1e20,
                 allow_nan=False, allow_infinity=False),
       st.sampled_from(FORMATS))
def test_quantize_idempotent(x, fmt):
    n, es = fmt
    q1 = float(P.posit_quantize(jnp.float64(x), n, es))
    q2 = float(P.posit_quantize(jnp.float64(q1), n, es))
    assert q1 == q2


@settings(max_examples=300, deadline=None)
@given(st.floats(min_value=1e-18, max_value=1e18), st.sampled_from(FORMATS))
def test_quantize_sign_symmetry(x, fmt):
    n, es = fmt
    qp = float(P.posit_quantize(jnp.float64(x), n, es))
    qn = float(P.posit_quantize(jnp.float64(-x), n, es))
    assert qp == -qn


@settings(max_examples=200, deadline=None)
@given(st.floats(min_value=1e-15, max_value=1e15), st.sampled_from(FORMATS))
def test_quantize_relative_error_bound(x, fmt):
    """Within the regime-flat region the error is bounded by the format's
    worst-case relative ULP; the tapered extremes are clamped instead."""
    from hypothesis import assume
    n, es = fmt
    useed_pow = (n - 2) * (1 << es)
    assume(np.exp2(-useed_pow) <= x <= np.exp2(useed_pow))
    q = float(P.posit_quantize(jnp.float64(x), n, es))
    scale = np.floor(np.log2(x))
    k = int(scale) >> es
    rlen = (k + 2) if k >= 0 else (1 - k)
    fbits = max(n - 1 - rlen - es, 0)
    assert abs(q - x) <= np.exp2(scale - fbits) * (1 + 1e-12)


def test_rne_ties_to_even_word():
    # P(8,0): between 1.0 (0x40) and 1.015625? No — neighbors of 1.0 are
    # 1 +- 1/64. Take the exact midpoint between consecutive posits and
    # check the even word wins.
    vals = decode_table(8, 0)
    pos = vals[1:128]
    for i in [20, 40, 63, 64, 90, 100]:
        lo, hi = pos[i], pos[i + 1]
        mid = (lo + hi) / 2
        q = float(P.posit_quantize(jnp.float64(mid), 8, 0))
        w_lo = i + 1
        expected = lo if w_lo % 2 == 0 else hi
        assert q == expected, (mid, q, expected)
