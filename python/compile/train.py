"""Build-time training of the Fig. 4 model zoo on the synthetic datasets.

Pure-JAX Adam on the f32 `forward_train` graph. Runs ONCE under
`make artifacts`; exports per-model weights (SPDW), the layer spec (JSON),
and f32 train/test accuracy (metrics.json). The Rust side then evaluates
the same weights under posit quantization for the Fig. 4 reproduction —
python never appears on the inference path.

Usage: python -m compile.train --out-dir ../artifacts/weights
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, model
from .weights_io import write_spdw

# (steps, batch, lr) per model — sized for a few minutes of CPU total.
TRAIN_CFG = {
    "mlp": (400, 64, 1e-3),
    "lenet5": (500, 64, 1e-3),
    "cnn5": (500, 64, 1e-3),
    "alexnet_mini": (400, 64, 1e-3),
    "vgg16_mini": (2000, 64, 1e-3),
    "alpha_cnn": (500, 64, 1e-3),
}


def adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, st, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = st["t"] + 1
    m = {k: b1 * st["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * st["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mhat = {k: m[k] / (1 - b1 ** t) for k in params}
    vhat = {k: v[k] / (1 - b2 ** t) for k in params}
    new = {k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps)
           for k in params}
    return new, {"m": m, "v": v, "t": t}


def train_model(name: str, data_dir: str, log=print):
    steps, batch, lr = TRAIN_CFG[name]
    ds = model.MODEL_DATASET[name]
    xtr, ytr, _ = datasets.read_spdd(os.path.join(data_dir,
                                                  f"{ds}_train.bin"))
    xte, yte, _ = datasets.read_spdd(os.path.join(data_dir,
                                                  f"{ds}_test.bin"))
    xtr, ytr = jnp.asarray(xtr), jnp.asarray(ytr.astype(np.int32))
    xte, yte = jnp.asarray(xte), jnp.asarray(yte.astype(np.int32))

    params = model.init_params(name, seed=0)
    st = adam_init(params)

    def loss_fn(p, x, y):
        return model.cross_entropy(model.forward_train(p, name, x), y)

    @jax.jit
    def step(p, s, x, y):
        loss, g = jax.value_and_grad(loss_fn)(p, x, y)
        p2, s2 = adam_update(p, g, s, lr)
        return p2, s2, loss

    rng = np.random.default_rng(42)
    t0 = time.time()
    loss_curve = []
    for i in range(steps):
        idx = rng.integers(0, xtr.shape[0], size=batch)
        params, st, loss = step(params, st, xtr[idx], ytr[idx])
        if i % 50 == 0 or i == steps - 1:
            loss_curve.append((i, float(loss)))
            log(f"  [{name}] step {i:4d} loss {float(loss):.4f}")

    @jax.jit
    def logits_fn(p, x):
        return model.forward_train(p, name, x)

    def eval_acc(x, y):
        accs, n = 0.0, 0
        for i in range(0, x.shape[0], 256):
            lg = logits_fn(params, x[i:i + 256])
            accs += float(jnp.sum((jnp.argmax(lg, 1) == y[i:i + 256])))
            n += int(x.shape[0] - i if i + 256 > x.shape[0] else 256)
        return accs / x.shape[0]

    tr_acc, te_acc = eval_acc(xtr, ytr), eval_acc(xte, yte)
    dt = time.time() - t0
    log(f"  [{name}] train_acc={tr_acc:.4f} test_acc={te_acc:.4f} "
        f"({dt:.1f}s)")
    return params, {"train_acc": tr_acc, "test_acc": te_acc,
                    "steps": steps, "seconds": dt,
                    "loss_curve": loss_curve}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts/weights")
    ap.add_argument("--models", default=",".join(TRAIN_CFG))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    data_dir = os.path.join(os.path.dirname(args.out_dir), "data")
    os.makedirs(data_dir, exist_ok=True)
    need = {model.MODEL_DATASET[m] for m in args.models.split(",")}
    missing = [d for d in need
               if not os.path.exists(os.path.join(data_dir,
                                                  f"{d}_train.bin"))]
    if missing:
        print(f"building synthetic datasets -> {data_dir}")
        datasets.build_all(data_dir)

    # merge with any existing metrics so partial retrains keep rows
    metrics = {}
    mpath = os.path.join(args.out_dir, "metrics.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            metrics = json.load(f)
    for name in args.models.split(","):
        print(f"training {name} ...")
        params, m = train_model(name, data_dir)
        write_spdw(os.path.join(args.out_dir, f"{name}.spdw"),
                   {k: np.asarray(v) for k, v in params.items()})
        with open(os.path.join(args.out_dir, f"{name}.json"), "w") as f:
            f.write(model.spec_json(name))
        metrics[name] = m
    with open(mpath, "w") as f:
        json.dump(metrics, f, indent=1)
    print("wrote", args.out_dir)


if __name__ == "__main__":
    main()
