"""SPDW flat weight container shared with the Rust loader (`nn::weights`).

Format (little-endian): magic 'SPDW', u32 version=1, u32 count, then per
tensor: u16 name_len, name bytes (utf-8), u8 ndim, u32 dims[ndim],
f32 data (row-major).
"""

from __future__ import annotations

import struct

import numpy as np


def write_spdw(path: str, tensors: dict) -> None:
    with open(path, "wb") as f:
        f.write(b"SPDW")
        f.write(struct.pack("<II", 1, len(tensors)))
        for name in sorted(tensors):
            arr = np.asarray(tensors[name], dtype="<f4")
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_spdw(path: str) -> dict:
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"SPDW"
        ver, count = struct.unpack("<II", f.read(8))
        assert ver == 1
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            (ndim,) = struct.unpack("<B", f.read(1))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * n), dtype="<f4")
            out[name] = data.reshape(dims).copy()
    return out
