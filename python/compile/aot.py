"""AOT export: lower the L2 posit inference graphs to HLO *text*.

HLO text (not `.serialize()`): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the `xla`
crate) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md and DESIGN.md §2.

Exports, per MODE in {f32, p8, p16, p32}:
  * mlp_<mode>_b1.hlo.txt, mlp_<mode>_b32.hlo.txt
  * lenet5_<mode>_b32.hlo.txt
  * quant_<mode>_1024.hlo.txt  (elementwise quantize — runtime smoke test)

Model graphs take the weights as leading arguments in sorted-name order,
followed by the input batch; the Rust runtime (`runtime::Executable`)
feeds the SPDW tensors in the same order. A manifest.json records the
argument signature of every artifact.

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels.posit_matmul import posit_quantize_op  # noqa: E402

MODES = ["f32", "p8", "p16", "p32"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_model(name: str, mode: str, batch: int):
    """Lower forward_posit(params..., x) with params as leading args."""
    spec = model.ZOO[name]
    params0 = model.init_params(name, seed=0)
    keys = sorted(params0)

    def fn(*args):
        params = dict(zip(keys, args[:-1]))
        return (model.forward_posit(params, name, args[-1], mode),)

    arg_specs = [jax.ShapeDtypeStruct(params0[k].shape, jnp.float32)
                 for k in keys]
    arg_specs.append(jax.ShapeDtypeStruct([batch] + spec["input"],
                                          jnp.float32))
    lowered = jax.jit(fn).lower(*arg_specs)
    sig = {"params": {k: list(params0[k].shape) for k in keys},
           "param_order": keys,
           "input": [batch] + spec["input"],
           "output": [batch, spec["classes"]]}
    return to_hlo_text(lowered), sig


def lower_quant(mode: str, n: int = 1024):
    def fn(x):
        return (posit_quantize_op(x, mode=mode),)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((n,), jnp.float32))
    return to_hlo_text(lowered), {"params": {}, "param_order": [],
                                  "input": [n], "output": [n]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="mlp,lenet5")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}

    for mode in MODES:
        text, sig = lower_quant(mode)
        fname = f"quant_{mode}_1024.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest[fname] = sig
        print(f"wrote {fname} ({len(text)} chars)")

    jobs = []
    for m in args.models.split(","):
        jobs.append((m, 32))
        if m == "mlp":
            jobs.append((m, 1))
    for name, batch in jobs:
        for mode in MODES:
            text, sig = lower_model(name, mode, batch)
            fname = f"{name}_{mode}_b{batch}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            manifest[fname] = sig
            print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
