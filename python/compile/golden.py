"""Golden-vector export for the Rust <-> Python posit cross-check.

The paper validates its RTL against SoftPosit with 1000 randomized cases
and reports exact agreement (§III). We reproduce that methodology with two
independent implementations — this jnp one and the Rust core — checked
bit-for-bit on:

  * the full decode table of every P8 word (exhaustive);
  * 4096 random encodes per format spanning sign/dynamic-range corners;
  * quantized dot products (the MAC contract: exact accumulation, one
    final RNE) — exact for P8/P16, +-1 ulp for P32 where the f64 quire
    proxy can differ from the true 512-bit quire.

File layout (little-endian u64 arrays), one file per check:
  golden/p8_decode.bin      256 x u64   f64-bits of decode(word)
  golden/<fmt>_encode.bin   4096 x (u64 input-bits, u64 word)
  golden/<fmt>_mac.bin      64 seqs x (32 x (u64 a-bits, u64 b-bits),
                            u64 expected-word)

Usage: python -m compile.golden --out-dir ../artifacts/golden
"""

from __future__ import annotations

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from .kernels import posit as P  # noqa: E402

FORMATS = {"p8": (8, 0), "p16": (16, 1), "p32": (32, 2)}


def random_inputs(n: int, rng: np.random.Generator) -> np.ndarray:
    """f64 samples covering sign combinations and wide dynamic range."""
    scales = np.exp2(rng.integers(-40, 40, size=n).astype(np.float64))
    x = rng.normal(size=n) * scales
    # sprinkle exact corners
    corners = np.array([0.0, 1.0, -1.0, 0.5, -0.5, 2.0, -2.0,
                        np.inf, -np.inf, np.nan, 1e30, -1e30, 1e-30,
                        65536.0, 1.0 / 65536.0, 3.0, -3.0, 1.5, -1.5])
    x[:len(corners)] = corners
    return x


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts/golden")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    # exhaustive P8 decode table
    words = jnp.arange(256, dtype=jnp.int64)
    vals = np.array(P.posit_decode(words, 8, 0), dtype=np.float64)
    vals.view(np.uint64).tofile(os.path.join(args.out_dir, "p8_decode.bin"))
    print("wrote p8_decode.bin")

    rng = np.random.default_rng(2024)
    for fmt, (n, es) in FORMATS.items():
        x = random_inputs(4096, rng)
        w = np.array(P.posit_encode(jnp.asarray(x), n, es),
                     dtype=np.int64).astype(np.uint64)
        out = np.empty(4096 * 2, dtype=np.uint64)
        out[0::2] = x.view(np.uint64)
        out[1::2] = w
        out.tofile(os.path.join(args.out_dir, f"{fmt}_encode.bin"))
        print(f"wrote {fmt}_encode.bin")

        # MAC sequences: operands pre-quantized to the format so both
        # sides accumulate identical exact products.
        seqs = []
        for s in range(64):
            a = np.array(P.posit_quantize(
                jnp.asarray(random_inputs(32, rng) /
                            np.exp2(20)), n, es), dtype=np.float64)
            b = np.array(P.posit_quantize(
                jnp.asarray(random_inputs(32, rng) /
                            np.exp2(20)), n, es), dtype=np.float64)
            a = np.nan_to_num(a, nan=0.0, posinf=0.0, neginf=0.0)
            b = np.nan_to_num(b, nan=0.0, posinf=0.0, neginf=0.0)
            acc = float(np.dot(a, b))
            word = int(np.array(P.posit_encode(jnp.float64(acc), n, es)))
            rec = np.empty(32 * 2 + 1, dtype=np.uint64)
            rec[0:64:2] = a.view(np.uint64)
            rec[1:64:2] = b.view(np.uint64)
            rec[64] = np.uint64(word)
            seqs.append(rec)
        np.concatenate(seqs).tofile(
            os.path.join(args.out_dir, f"{fmt}_mac.bin"))
        print(f"wrote {fmt}_mac.bin")


if __name__ == "__main__":
    main()
