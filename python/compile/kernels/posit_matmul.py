"""L1 Pallas kernels: the SPADE MAC hot-spot as posit-quantized matmuls.

Hardware adaptation (DESIGN.md §5): the paper's SIMD lane fusion — one wide
datapath running 4x Posit-8 / 2x Posit-16 / 1x Posit-32 MACs per cycle —
becomes MODE-dependent *BlockSpec tiling*: at equal VMEM budget the P8
kernel streams 4x the tile area of the P32 kernel per grid step (operands
model 8-bit storage), the matmul itself stays on the MXU path
(`jnp.dot`), and the quire's exact no-intermediate-rounding accumulation
becomes an f64 accumulator with a single posit RNE at the end.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO that both pytest and the
Rust runtime can run. Correctness is therefore the target of this layer;
TPU-perf is estimated structurally in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .posit import FORMATS, posit_quantize

# MODE -> (bm, bn) tile shape. P8 lanes are 4x denser than P32 lanes at the
# same VMEM footprint (8-bit vs 32-bit storage), mirroring the paper's
# 4x/2x/1x per-cycle throughput. K is kept whole inside the block so the
# accumulation models the quire: no intermediate rounding along K.
MODE_TILES = {
    "p8": (64, 64),
    "p16": (32, 64),
    "p32": (32, 32),
    "f32": (32, 32),
}


def _quant(x, mode: str):
    if mode == "f32":
        return x
    n, es = FORMATS[mode]
    return posit_quantize(x, n, es)


def _matmul_kernel(x_ref, w_ref, o_ref, *, mode: str, out_mode: str):
    x = x_ref[...].astype(jnp.float64)
    w = w_ref[...].astype(jnp.float64)
    xq = _quant(x, mode)
    wq = _quant(w, mode)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float64)
    o_ref[...] = _quant(acc, out_mode).astype(jnp.float32)


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, mode: str, relu: bool):
    x = x_ref[...].astype(jnp.float64)
    w = w_ref[...].astype(jnp.float64)
    b = b_ref[...].astype(jnp.float64)
    xq = _quant(x, mode)
    wq = _quant(w, mode)
    bq = _quant(b, mode)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float64) + bq
    out = _quant(acc, mode)
    if relu:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out.astype(jnp.float32)


def _pad_dim(d: int, b: int) -> int:
    return (d + b - 1) // b * b


@functools.partial(jax.jit, static_argnames=("mode", "out_mode"))
def posit_matmul(x, w, mode: str = "p16", out_mode: str | None = None):
    """Posit(MODE)-quantized matmul via a tiled Pallas kernel.

    x: [M, K] f32, w: [K, N] f32 -> [M, N] f32 on the posit grid.
    Shapes are padded to the MODE tile internally and cropped back.
    """
    out_mode = out_mode or mode
    bm, bn = MODE_TILES[mode]
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    mp, np_ = _pad_dim(m, bm), _pad_dim(n, bn)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, 0)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, np_ - n)))

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, mode=mode, out_mode=out_mode),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("mode", "relu"))
def posit_dense(x, w, b, mode: str = "p16", relu: bool = True):
    """Fused dense layer: posit matmul + bias in the quire + optional ReLU.

    x: [M, K], w: [K, N], b: [N] -> [M, N] f32 on the posit grid.
    """
    bm, bn = MODE_TILES[mode]
    m, k = x.shape
    _, n = w.shape
    mp, np_ = _pad_dim(m, bm), _pad_dim(n, bn)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, 0)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, np_ - n)))
    bp = jnp.pad(b.astype(jnp.float32), (0, np_ - n)).reshape(1, np_)

    out = pl.pallas_call(
        functools.partial(_dense_kernel, mode=mode, relu=relu),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("mode",))
def posit_quantize_op(x, mode: str = "p16"):
    """Elementwise posit quantization as a Pallas kernel (whole-array block).

    Models Stage 1/Stage 5 of the pipeline in isolation; used by the Rust
    runtime tests as a minimal PJRT artifact exercising posit semantics.
    """

    def kernel(x_ref, o_ref):
        o_ref[...] = _quant(x_ref[...].astype(jnp.float64), mode).astype(
            jnp.float32)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
