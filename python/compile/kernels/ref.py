"""Pure-jnp oracle for the L1 Pallas kernels.

Same math as `posit_matmul.py` without pallas: quantize operands to the
posit(n, es) grid, exact high-precision accumulation (f64 — the quire
proxy, see DESIGN.md §6), one final posit rounding. pytest checks the
Pallas kernels against these under hypothesis-driven shape/format sweeps.
"""

from __future__ import annotations

import jax.numpy as jnp

from .posit import FORMATS, posit_quantize


def quantize_ref(x, mode: str):
    """Elementwise posit quantization oracle (f32 passthrough)."""
    x = jnp.asarray(x, jnp.float64)
    if mode == "f32":
        return x
    n, es = FORMATS[mode]
    return posit_quantize(x, n, es)


def matmul_ref(x, w, mode: str, out_mode: str | None = None):
    """Posit MAC oracle: q(x) @ q(w) with exact accumulation, final round.

    Mirrors the SPADE pipeline: Stage 1-2 quantized operands and exact
    products, Stage 3 quire accumulation (no intermediate rounding),
    Stage 4-5 a single reconstruction + RNE at the end.
    """
    out_mode = out_mode or mode
    xq = quantize_ref(x, mode)
    wq = quantize_ref(w, mode)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float64)
    return quantize_ref(acc, out_mode)


def dense_ref(x, w, b, mode: str, relu: bool = True):
    """Dense layer oracle: posit matmul + bias into the quire + activation."""
    xq = quantize_ref(x, mode)
    wq = quantize_ref(w, mode)
    bq = quantize_ref(b, mode)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float64) + bq
    out = quantize_ref(acc, mode)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out
