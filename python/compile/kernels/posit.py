"""Vectorized posit(n, es) encode / decode / quantize in pure jnp integer ops.

This is the *golden twin* of the Rust core (`rust/src/posit/`): both sides
implement the identical assemble-then-round-to-nearest-even algorithm, and
`compile/golden.py` exports exhaustive/random vectors that `cargo test
golden_vs_python` checks bit-for-bit.

Why integer bit-manipulation instead of table lookups: it vectorizes on the
VPU, needs no 65536-entry constants in the kernel, and is the same algorithm
the SPADE RTL implements (LOD regime decode -> shift -> field extraction),
so the Pallas kernel structurally mirrors the datapath it models.

All functions operate on int64/float64 (jax_enable_x64 must be on — aot.py,
train.py and the tests set it). Posit special values: 0 -> 0,
NaR (1000...0) <- NaN/Inf. Rounding: round-to-nearest-even on the monotone
word encoding (the standard posit rounding), values in (0, minpos] round to
minpos, values >= maxpos clamp to maxpos.
"""

from __future__ import annotations

import jax.numpy as jnp

# Extra fraction bits carried through the assemble step before rounding.
# Wide enough that guard+sticky are exact for every format we support
# (P32 keeps <= 27 fraction bits; regime <= 31 bits; 29+2+31 = 62 < 63).
_F = 29

_F64_EXP_MASK = (1 << 11) - 1
_F64_FRAC_MASK = (1 << 52) - 1


def _msb_index(x):
    """Index of the highest set bit of positive int64 x (exact for x < 2^53).

    Implemented via the exponent field of the float64 conversion — there is
    no clz in jnp, but the conversion is exact below 2^53 which covers every
    field width we ever scan (<= 2^31).
    """
    f = jnp.asarray(x).astype(jnp.float64)
    bits = f.view(jnp.int64)
    return ((bits >> 52) & _F64_EXP_MASK) - 1023


def posit_encode(v, nbits: int, es: int):
    """Round float64 array `v` to the nearest posit(nbits, es) word (int64).

    Returns the canonical unsigned word in [0, 2^nbits).
    """
    v = jnp.asarray(v, jnp.float64)
    n = nbits
    es2 = 1 << es
    mask = (1 << n) - 1
    maxpos = (1 << (n - 1)) - 1
    nar = 1 << (n - 1)

    bits = v.view(jnp.int64)
    sign = (bits >> 63) & 1
    e_raw = (bits >> 52) & _F64_EXP_MASK
    frac52 = bits & _F64_FRAC_MASK

    is_zero = (e_raw == 0) & (frac52 == 0)
    is_nar = e_raw == _F64_EXP_MASK  # inf or nan
    # Subnormal float64 inputs are far below minpos for every posit format
    # we support — fold them into the "tiny" clamp below by treating the
    # scale as very negative.
    sc = jnp.where(e_raw == 0, jnp.int64(-4096), e_raw - 1023)

    k = sc >> es  # floor division (arithmetic shift)
    ex = sc - (k << es)  # in [0, es2)

    # Regime clamps: k >= n-2 saturates to maxpos, k <= -(n-1) to minpos.
    too_big = k >= (n - 2)
    too_small = k <= -(n - 1)
    k_c = jnp.clip(k, -(n - 2), n - 3)
    rlen = jnp.where(k_c >= 0, k_c + 2, 1 - k_c)

    # Assemble [regime | exponent | fraction(_F bits)] into one integer.
    regime_val = jnp.where(k_c >= 0, ((jnp.int64(1) << (k_c + 1)) - 1) << 1,
                           jnp.int64(1))
    frac_hi = frac52 >> (52 - _F)
    sticky_low = (frac52 & ((1 << (52 - _F)) - 1)) != 0

    x = (regime_val << (es + _F)) | (ex.astype(jnp.int64) << _F) | frac_hi
    shift = rlen + es + _F - (n - 1)  # always >= 1 given _F >= n
    q = x >> shift
    round_bit = (x >> (shift - 1)) & 1
    sticky = ((x & ((jnp.int64(1) << (shift - 1)) - 1)) != 0) | sticky_low
    q = q + (round_bit & (sticky.astype(jnp.int64) | (q & 1)))

    # Monotone-word rounding can only move within the positive range;
    # clamp the extremes per the posit standard (no overflow to NaR,
    # no underflow to zero).
    q = jnp.where(too_big, jnp.int64(maxpos), q)
    q = jnp.where(too_small, jnp.int64(1), q)
    q = jnp.clip(q, 1, maxpos)

    word = jnp.where(sign == 1, (-q) & mask, q)
    word = jnp.where(is_zero, jnp.int64(0), word)
    word = jnp.where(is_nar, jnp.int64(nar), word)
    return word.astype(jnp.int64)


def posit_decode(words, nbits: int, es: int):
    """Decode posit(nbits, es) words (int64, canonical unsigned) to float64.

    NaR decodes to NaN.
    """
    p = jnp.asarray(words, jnp.int64) & ((1 << nbits) - 1)
    n = nbits
    es2 = 1 << es
    mask = (1 << n) - 1
    nar = 1 << (n - 1)

    is_zero = p == 0
    is_nar = p == nar

    s = (p >> (n - 1)) & 1
    mag = jnp.where(s == 1, (-p) & mask, p)
    body = mag & ((1 << (n - 1)) - 1)  # bits n-2..0
    r0 = (mag >> (n - 2)) & 1

    # Regime run length via MSB scan of (body or its complement).
    body_mask = (1 << (n - 1)) - 1
    t_ones = (~body) & body_mask  # first 0 marks end of a 1-run
    t_zeros = body
    # Guard against all-ones / all-zeros bodies (j scan on 0 is undefined);
    # substitute 1 and fix up afterwards.
    t1 = jnp.where(t_ones == 0, jnp.int64(1), t_ones)
    t0 = jnp.where(t_zeros == 0, jnp.int64(1), t_zeros)
    j_ones = _msb_index(t1)
    j_zeros = _msb_index(t0)

    # r0 == 1: run of m ones from bit n-2 down; first zero at j_ones.
    m_ones = (n - 2) - j_ones
    k_ones = m_ones - 1
    # all-ones body: k = n-2, no terminator, no exp/frac
    k_ones = jnp.where(t_ones == 0, jnp.int64(n - 2), k_ones)
    j_term_ones = jnp.where(t_ones == 0, jnp.int64(-1), j_ones)

    # r0 == 0: run of zeros ends at the terminating 1 at j_zeros.
    m_zeros = (n - 2) - j_zeros
    k_zeros = -m_zeros
    # body == 0 with mag != 0 cannot happen for valid nonzero posits
    j_term_zeros = jnp.where(t_zeros == 0, jnp.int64(-1), j_zeros)

    k = jnp.where(r0 == 1, k_ones, k_zeros)
    j = jnp.where(r0 == 1, j_term_ones, j_term_zeros)  # terminator position

    # Bits below the terminator: first min(es, j) are exponent MSBs.
    j_pos = jnp.maximum(j, 0)
    have = jnp.minimum(jnp.int64(es), j_pos)
    field = body & ((jnp.int64(1) << j_pos) - 1)
    ex = (field >> (j_pos - have)) << (es - have)
    fbits = j_pos - have
    frac = field & ((jnp.int64(1) << fbits) - 1)

    scale = k * es2 + ex
    # Assemble the float64 directly from bit fields — jnp.exp2 is not
    # guaranteed bit-exact on every backend, and decode values must be
    # exact for the golden cross-check with the Rust core. The posit
    # scale range (|scale| <= 120 for P32) is always a normal float64.
    val_bits = ((1023 + scale) << 52) | (frac << (52 - fbits))
    val = val_bits.view(jnp.float64)
    val = jnp.where(s == 1, -val, val)
    val = jnp.where(is_zero, 0.0, val)
    val = jnp.where(is_nar, jnp.float64(jnp.nan), val)
    return val


def posit_quantize(v, nbits: int, es: int):
    """Round float64 array to the nearest posit(nbits, es) value (float64)."""
    return posit_decode(posit_encode(v, nbits, es), nbits, es)


# Standard SPADE formats: MODE 0/1/2 from the paper's 2-bit MODE signal.
FORMATS = {
    "p8": (8, 0),
    "p16": (16, 1),
    "p32": (32, 2),
}


def quantize_mode(v, mode: str):
    """Quantize through one of the SPADE MODE formats, or pass through f32."""
    if mode == "f32":
        return jnp.asarray(v, jnp.float64)
    n, es = FORMATS[mode]
    return posit_quantize(v, n, es)
