"""Synthetic procedural datasets standing in for MNIST / CIFAR-10 /
CIFAR-100 / alphabet (DESIGN.md §1: dataset substitution).

Fig. 4's claim is iso-accuracy of posit vs float *inference pipelines*, a
property of the numeric format, not of the specific images. These
generators produce deterministic labelled datasets exercising the same
quantized inference path:

* digits / alphabet — 5x7 glyph bitmaps upscaled to 28x28 with random
  shift, scale jitter, stroke-intensity jitter and pixel noise
  (MNIST-like / EMNIST-letters-like);
* class-conditional RGB textures — per-class frequency/orientation/color
  signatures plus instance-level phase, rotation-ish shear and noise
  (CIFAR-10/100-like).

Datasets are generated once at build time and written under
`artifacts/data/` in a flat binary format (SPDD) that the Rust side loads;
this avoids any cross-language RNG drift between training and evaluation.

SPDD format (little-endian): magic 'SPDD', u32 version=1, u32 n, u32 h,
u32 w, u32 c, u32 nclasses, u8 labels[n], f32 data[n*h*w*c] (NHWC, [0,1]).
"""

from __future__ import annotations

import os
import struct

import numpy as np

# --- 5x7 glyph font (rows of 5 chars, '#' = on) -------------------------

_FONT = {
    "0": ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    "1": ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    "2": ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    "3": ["11111", "00010", "00100", "00010", "00001", "10001", "01110"],
    "4": ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    "5": ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    "6": ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    "7": ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    "8": ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    "9": ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
    "A": ["01110", "10001", "10001", "11111", "10001", "10001", "10001"],
    "B": ["11110", "10001", "10001", "11110", "10001", "10001", "11110"],
    "C": ["01110", "10001", "10000", "10000", "10000", "10001", "01110"],
    "D": ["11100", "10010", "10001", "10001", "10001", "10010", "11100"],
    "E": ["11111", "10000", "10000", "11110", "10000", "10000", "11111"],
    "F": ["11111", "10000", "10000", "11110", "10000", "10000", "10000"],
    "G": ["01110", "10001", "10000", "10111", "10001", "10001", "01111"],
    "H": ["10001", "10001", "10001", "11111", "10001", "10001", "10001"],
    "I": ["01110", "00100", "00100", "00100", "00100", "00100", "01110"],
    "J": ["00111", "00010", "00010", "00010", "00010", "10010", "01100"],
    "K": ["10001", "10010", "10100", "11000", "10100", "10010", "10001"],
    "L": ["10000", "10000", "10000", "10000", "10000", "10000", "11111"],
    "M": ["10001", "11011", "10101", "10101", "10001", "10001", "10001"],
    "N": ["10001", "10001", "11001", "10101", "10011", "10001", "10001"],
    "O": ["01110", "10001", "10001", "10001", "10001", "10001", "01110"],
    "P": ["11110", "10001", "10001", "11110", "10000", "10000", "10000"],
    "Q": ["01110", "10001", "10001", "10001", "10101", "10010", "01101"],
    "R": ["11110", "10001", "10001", "11110", "10100", "10010", "10001"],
    "S": ["01111", "10000", "10000", "01110", "00001", "00001", "11110"],
    "T": ["11111", "00100", "00100", "00100", "00100", "00100", "00100"],
    "U": ["10001", "10001", "10001", "10001", "10001", "10001", "01110"],
    "V": ["10001", "10001", "10001", "10001", "10001", "01010", "00100"],
    "W": ["10001", "10001", "10001", "10101", "10101", "11011", "10001"],
    "X": ["10001", "10001", "01010", "00100", "01010", "10001", "10001"],
    "Y": ["10001", "10001", "01010", "00100", "00100", "00100", "00100"],
    "Z": ["11111", "00001", "00010", "00100", "01000", "10000", "11111"],
}

_DIGITS = "0123456789"
_LETTERS = "ABCDEFGHIJKLMNOPQRSTUVWXYZ"


def _glyph_array(ch: str) -> np.ndarray:
    rows = _FONT[ch]
    return np.array([[1.0 if c == "1" else 0.0 for c in r] for r in rows],
                    dtype=np.float32)


def _render_glyph(ch: str, rng: np.random.Generator, size: int = 28
                  ) -> np.ndarray:
    """Upscale a 5x7 glyph with jittered placement/scale/intensity/noise."""
    g = _glyph_array(ch)
    # jitter scale: glyph occupies roughly 60-90% of the canvas
    sh = rng.uniform(0.60, 0.90)
    sw = rng.uniform(0.60, 0.90)
    th = max(7, int(round(size * sh)))
    tw = max(5, int(round(size * sw * 5 / 7)))
    # nearest-neighbour upscale with fractional sampling (cheap, dependency
    # free, and identical semantics on every platform)
    yy = np.minimum((np.arange(th) * 7 // th), 6)
    xx = np.minimum((np.arange(tw) * 5 // tw), 4)
    big = g[np.ix_(yy, xx)]
    # stroke intensity jitter + slight blur via 3x3 box smoothing
    big = big * rng.uniform(0.75, 1.0)
    img = np.zeros((size, size), dtype=np.float32)
    oy = rng.integers(0, size - th + 1)
    ox = rng.integers(0, size - tw + 1)
    img[oy:oy + th, ox:ox + tw] = big
    k = np.pad(img, 1)
    img = (k[:-2, :-2] + k[:-2, 1:-1] + k[:-2, 2:] +
           k[1:-1, :-2] + 2 * k[1:-1, 1:-1] + k[1:-1, 2:] +
           k[2:, :-2] + k[2:, 1:-1] + k[2:, 2:]) / 10.0
    img += rng.normal(0, 0.06, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)[..., None]  # HWC, C=1


def _render_texture(cls: int, nclasses: int, rng: np.random.Generator,
                    size: int = 32) -> np.ndarray:
    """Class-conditional RGB texture (CIFAR-like stand-in).

    The class identity is carried by a deterministic per-class signature
    (two spatial frequencies, orientation, color mixing); the instance
    varies phase, shift and noise so the task is learnable but not trivial.
    """
    crng = np.random.default_rng(1234567 + cls)  # per-class signature
    f1 = crng.uniform(1.0, 6.0)
    f2 = crng.uniform(1.0, 6.0)
    theta = crng.uniform(0, np.pi)
    color = crng.uniform(0.2, 1.0, size=(3, 2))
    blob_c = crng.uniform(0.2, 0.8, size=3)

    ph1 = rng.uniform(0, 2 * np.pi)
    ph2 = rng.uniform(0, 2 * np.pi)
    y, x = np.mgrid[0:size, 0:size] / size
    u = np.cos(theta) * x + np.sin(theta) * y
    v = -np.sin(theta) * x + np.cos(theta) * y
    a = 0.5 + 0.5 * np.sin(2 * np.pi * f1 * u + ph1)
    b = 0.5 + 0.5 * np.sin(2 * np.pi * f2 * v + ph2)
    img = np.stack([color[c, 0] * a + color[c, 1] * b for c in range(3)],
                   axis=-1).astype(np.float32) / 2.0
    # instance blob
    cy, cx = rng.uniform(0.2, 0.8, 2)
    r2 = (y - cy) ** 2 + (x - cx) ** 2
    blob = np.exp(-r2 / 0.02).astype(np.float32)
    img += blob[..., None] * blob_c[None, None, :] * 0.5
    img += rng.normal(0, 0.05, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


# --- dataset builders ----------------------------------------------------

def make_glyph_dataset(chars: str, n: int, seed: int):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, len(chars), size=n).astype(np.uint8)
    imgs = np.stack([_render_glyph(chars[l], rng) for l in labels])
    return imgs.astype(np.float32), labels


def make_texture_dataset(nclasses: int, n: int, seed: int, size: int = 32):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, nclasses, size=n).astype(np.uint8)
    imgs = np.stack([_render_texture(int(l), nclasses, rng, size)
                     for l in labels])
    return imgs.astype(np.float32), labels


SPECS = {
    # name: (builder, nclasses, train_n, test_n)
    "mnist_syn": (lambda n, s: make_glyph_dataset(_DIGITS, n, s), 10,
                  3000, 600),
    "alpha_syn": (lambda n, s: make_glyph_dataset(_LETTERS, n, s), 26,
                  3900, 780),
    "cifar10_syn": (lambda n, s: make_texture_dataset(10, n, s), 10,
                    3000, 600),
    "cifar100_syn": (lambda n, s: make_texture_dataset(100, n, s), 100,
                     6000, 1200),
}


def write_spdd(path: str, imgs: np.ndarray, labels: np.ndarray,
               nclasses: int) -> None:
    n, h, w, c = imgs.shape
    with open(path, "wb") as f:
        f.write(b"SPDD")
        f.write(struct.pack("<IIIIII", 1, n, h, w, c, nclasses))
        f.write(labels.astype(np.uint8).tobytes())
        f.write(imgs.astype("<f4").tobytes())


def read_spdd(path: str):
    with open(path, "rb") as f:
        assert f.read(4) == b"SPDD"
        ver, n, h, w, c, nclasses = struct.unpack("<IIIIII", f.read(24))
        assert ver == 1
        labels = np.frombuffer(f.read(n), dtype=np.uint8)
        data = np.frombuffer(f.read(n * h * w * c * 4), dtype="<f4")
    return data.reshape(n, h, w, c).copy(), labels.copy(), nclasses


def build_all(out_dir: str, seed: int = 7):
    os.makedirs(out_dir, exist_ok=True)
    built = {}
    for name, (builder, nclasses, ntr, nte) in SPECS.items():
        tr_imgs, tr_lab = builder(ntr, seed)
        te_imgs, te_lab = builder(nte, seed + 1)
        write_spdd(os.path.join(out_dir, f"{name}_train.bin"),
                   tr_imgs, tr_lab, nclasses)
        write_spdd(os.path.join(out_dir, f"{name}_test.bin"),
                   te_imgs, te_lab, nclasses)
        built[name] = (tr_imgs.shape, te_imgs.shape)
    return built
