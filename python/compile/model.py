"""L2 — JAX model zoo: forward/backward graphs built on the L1 kernels.

Two forward paths per model:

* `forward_train` — plain f32 jnp/lax ops (fast CPU training at build
  time; `train.py` differentiates through it);
* `forward_posit` — the inference graph used for AOT export and accuracy
  evaluation: every MAC layer routed through the L1 Pallas posit kernels
  (conv lowered to im2col + `posit_dense`), mirroring execution on the
  SPADE systolic array where conv is mapped as GEMM (Fig. 3).

Models are described by a declarative layer spec (JSON-serializable) that
the Rust side (`nn::model`) consumes verbatim, so both languages build the
identical graph over the identical weights.

Layout conventions (shared with Rust): activations NHWC, conv weights
HWIO, im2col patch ordering (ky, kx, c), maxpool 2x2/2 valid.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.posit_matmul import posit_dense, posit_matmul

# --- model zoo -----------------------------------------------------------
# Layer kinds: conv(k, out, pad), maxpool(k), relu, flatten, dense(out).
# ReLU is folded into conv/dense via `relu: true` (the systolic PE applies
# activation at drain time).

ZOO = {
    "mlp": {
        "input": [28, 28, 1], "classes": 10,
        "layers": [
            {"kind": "flatten"},
            {"kind": "dense", "out": 128, "relu": True},
            {"kind": "dense", "out": 10, "relu": False},
        ],
    },
    "lenet5": {
        "input": [28, 28, 1], "classes": 10,
        "layers": [
            {"kind": "conv", "k": 5, "out": 6, "pad": "valid", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "conv", "k": 5, "out": 16, "pad": "valid", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "flatten"},
            {"kind": "dense", "out": 120, "relu": True},
            {"kind": "dense", "out": 84, "relu": True},
            {"kind": "dense", "out": 10, "relu": False},
        ],
    },
    "cnn5": {
        "input": [32, 32, 3], "classes": 10,
        "layers": [
            {"kind": "conv", "k": 3, "out": 32, "pad": "same", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "conv", "k": 3, "out": 64, "pad": "same", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "conv", "k": 3, "out": 64, "pad": "same", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "flatten"},
            {"kind": "dense", "out": 128, "relu": True},
            {"kind": "dense", "out": 10, "relu": False},
        ],
    },
    "alexnet_mini": {
        "input": [32, 32, 3], "classes": 10,
        "layers": [
            {"kind": "conv", "k": 3, "out": 48, "pad": "same", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "conv", "k": 3, "out": 96, "pad": "same", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "conv", "k": 3, "out": 96, "pad": "same", "relu": True},
            {"kind": "conv", "k": 3, "out": 64, "pad": "same", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "flatten"},
            {"kind": "dense", "out": 256, "relu": True},
            {"kind": "dense", "out": 10, "relu": False},
        ],
    },
    "vgg16_mini": {
        # VGG-16 structure at 1/8 width for build-time CPU training
        "input": [32, 32, 3], "classes": 100,
        "layers": [
            {"kind": "conv", "k": 3, "out": 16, "pad": "same", "relu": True},
            {"kind": "conv", "k": 3, "out": 16, "pad": "same", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "conv", "k": 3, "out": 32, "pad": "same", "relu": True},
            {"kind": "conv", "k": 3, "out": 32, "pad": "same", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "conv", "k": 3, "out": 64, "pad": "same", "relu": True},
            {"kind": "conv", "k": 3, "out": 64, "pad": "same", "relu": True},
            {"kind": "conv", "k": 3, "out": 64, "pad": "same", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "conv", "k": 3, "out": 96, "pad": "same", "relu": True},
            {"kind": "conv", "k": 3, "out": 96, "pad": "same", "relu": True},
            {"kind": "conv", "k": 3, "out": 96, "pad": "same", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "conv", "k": 3, "out": 96, "pad": "same", "relu": True},
            {"kind": "conv", "k": 3, "out": 96, "pad": "same", "relu": True},
            {"kind": "conv", "k": 3, "out": 96, "pad": "same", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "flatten"},
            {"kind": "dense", "out": 256, "relu": True},
            {"kind": "dense", "out": 100, "relu": False},
        ],
    },
    "alpha_cnn": {
        # the paper's 4-layer CNN for alphabet recognition
        "input": [28, 28, 1], "classes": 26,
        "layers": [
            {"kind": "conv", "k": 3, "out": 16, "pad": "same", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "conv", "k": 3, "out": 32, "pad": "same", "relu": True},
            {"kind": "maxpool", "k": 2},
            {"kind": "flatten"},
            {"kind": "dense", "out": 64, "relu": True},
            {"kind": "dense", "out": 26, "relu": False},
        ],
    },
}

# dataset each model is trained/evaluated on (paper Fig. 4 pairing)
MODEL_DATASET = {
    "mlp": "mnist_syn",
    "lenet5": "mnist_syn",
    "cnn5": "cifar10_syn",
    "alexnet_mini": "cifar10_syn",
    "vgg16_mini": "cifar100_syn",
    "alpha_cnn": "alpha_syn",
}


def _out_hw(h, w, k, pad):
    if pad == "same":
        return h, w
    return h - k + 1, w - k + 1


def shapes_through(name: str):
    """Yield (layer, in_shape, out_shape) walking the spec symbolically."""
    spec = ZOO[name]
    h, w, c = spec["input"]
    feat = None
    out = []
    for layer in spec["layers"]:
        kind = layer["kind"]
        ishape = (h, w, c) if feat is None else (feat,)
        if kind == "conv":
            h, w = _out_hw(h, w, layer["k"], layer["pad"])
            c = layer["out"]
            oshape = (h, w, c)
        elif kind == "maxpool":
            h, w = h // layer["k"], w // layer["k"]
            oshape = (h, w, c)
        elif kind == "flatten":
            feat = h * w * c
            oshape = (feat,)
        elif kind == "dense":
            feat = layer["out"]
            oshape = (feat,)
        elif kind == "relu":
            oshape = ishape
        else:
            raise ValueError(kind)
        out.append((layer, ishape, oshape))
    return out


def init_params(name: str, seed: int = 0):
    """He-init parameters keyed 'layer{i}/w' and 'layer{i}/b'."""
    rng = np.random.default_rng(seed)
    params = {}
    for i, (layer, ishape, _) in enumerate(shapes_through(name)):
        kind = layer["kind"]
        if kind == "conv":
            k, o = layer["k"], layer["out"]
            cin = ishape[2]
            fan_in = k * k * cin
            params[f"layer{i}/w"] = (rng.normal(0, np.sqrt(2 / fan_in),
                                                (k, k, cin, o))
                                     .astype(np.float32))
            params[f"layer{i}/b"] = np.zeros(o, np.float32)
        elif kind == "dense":
            fan_in = ishape[0]
            params[f"layer{i}/w"] = (rng.normal(0, np.sqrt(2 / fan_in),
                                                (fan_in, layer["out"]))
                                     .astype(np.float32))
            params[f"layer{i}/b"] = np.zeros(layer["out"], np.float32)
    return {k: jnp.asarray(v) for k, v in params.items()}


# --- f32 training forward (plain lax ops, fast & differentiable) ---------

def forward_train(params, name: str, x):
    """x: [N, H, W, C] f32 -> logits [N, classes]."""
    spec = ZOO[name]
    for i, layer in enumerate(spec["layers"]):
        kind = layer["kind"]
        if kind == "conv":
            w = params[f"layer{i}/w"]
            b = params[f"layer{i}/b"]
            pad = "SAME" if layer["pad"] == "same" else "VALID"
            x = jax.lax.conv_general_dilated(
                x, w, window_strides=(1, 1), padding=pad,
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
            if layer.get("relu"):
                x = jnp.maximum(x, 0.0)
        elif kind == "maxpool":
            k = layer["k"]
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1),
                "VALID")
        elif kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif kind == "dense":
            x = x @ params[f"layer{i}/w"] + params[f"layer{i}/b"]
            if layer.get("relu"):
                x = jnp.maximum(x, 0.0)
    return x


# --- posit inference forward (L1 Pallas kernels, conv as im2col GEMM) ----

def _im2col(x, k: int, pad: str):
    """[N,H,W,C] -> [N,Ho,Wo,k*k*C] with (ky, kx, c) patch ordering."""
    if pad == "same":
        p = (k - 1) // 2
        q = k - 1 - p
        x = jnp.pad(x, ((0, 0), (p, q), (p, q), (0, 0)))
    n, h, w, c = x.shape
    ho, wo = h - k + 1, w - k + 1
    cols = [x[:, i:i + ho, j:j + wo, :] for i in range(k) for j in range(k)]
    return jnp.concatenate(cols, axis=-1)


def forward_posit(params, name: str, x, mode: str):
    """Posit(MODE) inference graph — every MAC through the L1 kernel."""
    spec = ZOO[name]
    for i, layer in enumerate(spec["layers"]):
        kind = layer["kind"]
        if kind == "conv":
            w = params[f"layer{i}/w"]
            b = params[f"layer{i}/b"]
            k = layer["k"]
            patches = _im2col(x, k, layer["pad"])
            n, ho, wo, pc = patches.shape
            wmat = w.reshape(-1, w.shape[-1])
            y = posit_dense(patches.reshape(-1, pc), wmat, b, mode=mode,
                            relu=bool(layer.get("relu")))
            x = y.reshape(n, ho, wo, -1)
        elif kind == "maxpool":
            k = layer["k"]
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1),
                "VALID")
        elif kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif kind == "dense":
            x = posit_dense(x, params[f"layer{i}/w"], params[f"layer{i}/b"],
                            mode=mode, relu=bool(layer.get("relu")))
    return x


# --- losses / metrics -----------------------------------------------------

def cross_entropy(logits, labels):
    logz = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logz, labels[:, None], axis=1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(
        jnp.float32))


def spec_json(name: str) -> str:
    spec = dict(ZOO[name])
    spec["name"] = name
    spec["dataset"] = MODEL_DATASET[name]
    return json.dumps(spec, indent=1)
