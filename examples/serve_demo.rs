//! Serving demo: the precision-adaptive coordinator under synthetic
//! Poisson traffic with mixed precision pins, reporting latency
//! percentiles per mode, per-shard load, and end-to-end throughput.
//!
//! The engine is selected automatically (`Coordinator::start_auto`):
//! PJRT artifacts when `artifacts/manifest.json` exists, otherwise the
//! sharded planar posit kernel on trained or synthetic weights — so
//! the demo runs on a bare checkout.
//!
//! Run: `cargo run --release --example serve_demo
//!       [-- --requests 512 --rate-us 150 --policy balanced
//!           --shards 2 --batch 16]`

use anyhow::Result;

use spade::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig,
                         InferenceRequest, RoutePolicy, ServeBackend};
use spade::data::TrafficGen;
use spade::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let requests: usize = args.num_or("requests", 512);
    let rate_us: u64 = args.num_or("rate-us", 150);
    let shards: usize = args.num_or("shards", 0); // 0 = auto
    let batch: usize = args.num_or("batch", 32);
    let policy = match args.get_or("policy", "energy").as_str() {
        "accuracy" => RoutePolicy::AccuracyFirst,
        "balanced" => RoutePolicy::Balanced,
        _ => RoutePolicy::EnergyFirst,
    };

    let model = args.get_or("model", "mlp");
    println!("starting coordinator (model={model}, policy={policy:?}, \
              shards={}) ...",
             if shards == 0 { "auto".to_string() }
             else { shards.to_string() });
    let (coord, backend) = Coordinator::start_auto(CoordinatorConfig {
        model,
        policy,
        shards,
        batcher: BatcherConfig { target: batch.max(1),
                                 ..BatcherConfig::default() },
    })?;
    match backend {
        ServeBackend::Pjrt => println!("engine: PJRT artifacts"),
        ServeBackend::PlanarTrained => {
            println!("engine: sharded planar kernel (trained weights)")
        }
        ServeBackend::PlanarSynthetic => {
            println!("engine: sharded planar kernel (synthetic model — \
                      run `make artifacts` for trained weights)")
        }
    }

    let mut traffic = TrafficGen::new(99, rate_us, coord.input_len());
    println!("submitting {requests} requests (mean inter-arrival \
              {rate_us} us; ~25% pin an explicit precision) ...\n");

    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for r in traffic.burst(requests) {
        pending.push(coord.submit(InferenceRequest {
            id: r.id,
            input: r.input,
            mode: r.mode,
        }));
    }
    let mut mode_counts = std::collections::BTreeMap::new();
    for rx in pending {
        let resp = rx.recv()?;
        *mode_counts.entry(format!("{:?}", resp.mode)).or_insert(0u32)
            += 1;
    }
    let wall = t0.elapsed();

    let metrics = coord.shutdown();
    println!("{}", metrics.summary());
    println!("batch-mode distribution: {mode_counts:?}");
    println!("end-to-end: {requests} requests in {:.2}s -> {:.0} req/s",
             wall.as_secs_f64(),
             requests as f64 / wall.as_secs_f64());
    println!("\n(the energy-first policy routes unpinned traffic to \
              P8x4 — 4 lanes/cycle — while explicit P16/P32 pins are \
              honored per batch; each shard owns a persistent planar \
              session whose weight plans decode once, and all shards \
              share the kernel worker pool. compare --policy accuracy, \
              --shards 1 vs 4)");
    Ok(())
}
