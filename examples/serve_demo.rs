//! Serving demo: the precision-adaptive engine under synthetic
//! Poisson traffic with mixed precision pins, reporting latency
//! percentiles per mode, per-shard load, and end-to-end throughput.
//!
//! Construction goes through the unified facade
//! (`spade::api::EngineBuilder`): `SPADE_*` environment knobs are
//! parsed once (`from_env`), CLI flags layer on top, and one
//! validated `EngineConfig` drives batching, sharding, kernel tuning
//! and metrics. The serving backend is selected automatically: PJRT
//! artifacts when `artifacts/manifest.json` exists, otherwise the
//! sharded planar posit kernel on trained or synthetic weights — so
//! the demo runs on a bare checkout.
//!
//! Run: `cargo run --release --example serve_demo
//!       [-- --requests 512 --rate-us 150 --policy balanced
//!           --shards 2 --batch 16 --affinity pinned-mode
//!           --stats-json serve_stats.json]`

use std::time::Duration;

use anyhow::Result;

use spade::api::{EngineBuilder, RoutePolicy, ServeBackend,
                 ShardAffinity};
use spade::coordinator::InferenceRequest;
use spade::data::TrafficGen;
use spade::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let requests: usize = args.num_or("requests", 512);
    let rate_us: u64 = args.num_or("rate-us", 150);
    let shards: usize = args.num_or("shards", 0); // 0 = auto
    let batch: usize = args.num_or("batch", 32);
    let policy = match args.get_or("policy", "energy").as_str() {
        "accuracy" => RoutePolicy::AccuracyFirst,
        "balanced" => RoutePolicy::Balanced,
        _ => RoutePolicy::EnergyFirst,
    };
    let affinity = match args.get_or("affinity", "least-loaded")
        .as_str()
    {
        "pinned-mode" => ShardAffinity::PinnedMode,
        _ => ShardAffinity::LeastLoaded,
    };

    let model = args.get_or("model", "mlp");
    println!("building engine (model={model}, policy={policy:?}, \
              shards={}) ...",
             if shards == 0 { "auto".to_string() }
             else { shards.to_string() });
    let mut builder = EngineBuilder::from_env()?
        .model(model)
        .policy(policy)
        .shards(shards)
        .affinity(affinity)
        .batch(batch.max(1));
    if let Some(path) = args.options.get("stats-json") {
        builder = builder
            .stats_json(path)
            .stats_interval(Duration::from_millis(500));
    }
    let engine = builder.build()?;
    let handle = engine.serve()?;
    match handle.backend() {
        Some(ServeBackend::Pjrt) => {
            println!("backend: PJRT artifacts")
        }
        Some(ServeBackend::PlanarTrained) => {
            println!("backend: sharded planar kernel (trained weights)")
        }
        Some(ServeBackend::PlanarSynthetic) | None => {
            println!("backend: sharded planar kernel (synthetic model \
                      — run `make artifacts` for trained weights)")
        }
    }

    let mut traffic = TrafficGen::new(99, rate_us, handle.input_len());
    println!("submitting {requests} requests (mean inter-arrival \
              {rate_us} us; ~25% pin an explicit precision) ...\n");

    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for r in traffic.burst(requests) {
        // The demo serves unbounded queues (no --max-queue knob
        // here), so admission never rejects — but submit_with_retry
        // is the pattern a bounded fleet edge uses: honor the
        // server's retry_after_ms hint (with deterministic jitter)
        // for a few attempts before giving up. ? keeps it honest.
        pending.push(handle.submit_with_retry(
            InferenceRequest {
                id: r.id,
                input: r.input,
                mode: r.mode,
                deadline_ms: None,
            },
            4,
        )?);
    }
    let mut mode_counts = std::collections::BTreeMap::new();
    let mut degraded = 0u32;
    for rx in pending {
        // Outer ? = coordinator hung up; inner ? = typed per-request
        // failure (deadline, shard death) — none expected here.
        let resp = rx.recv()??;
        *mode_counts.entry(format!("{:?}", resp.mode)).or_insert(0u32)
            += 1;
        if resp.degraded {
            degraded += 1;
        }
    }
    let wall = t0.elapsed();

    let metrics = handle.shutdown();
    println!("{}", metrics.summary());
    println!("batch-mode distribution: {mode_counts:?}");
    if degraded > 0 {
        println!("degraded under load: {degraded}");
    }
    println!("end-to-end: {requests} requests in {:.2}s -> {:.0} req/s",
             wall.as_secs_f64(),
             requests as f64 / wall.as_secs_f64());
    if let Some(path) = args.options.get("stats-json") {
        println!("stats dump (periodic + final): {path}");
    }
    println!("\n(the energy-first policy routes unpinned traffic to \
              P8x4 — 4 lanes/cycle — while explicit P16/P32 pins are \
              honored per batch; each shard owns a persistent planar \
              session whose weight plans decode once, and all shards \
              share the kernel worker pool. compare --policy accuracy, \
              --shards 1 vs 4, --affinity pinned-mode)");
    Ok(())
}
