//! Quickstart: posit arithmetic, the SPADE engine, and one model layer.
//!
//! Run: `cargo run --release --example quickstart`

use spade::engine::{pack_lanes, MacEngine, Mode};
use spade::nn::{self, Backend, Model, Precision, Tensor};
use spade::posit::{Quire, P16, P8};

fn main() -> anyhow::Result<()> {
    // --- 1. posit arithmetic -------------------------------------------
    let a = P8::from_f64(1.5);
    let b = P8::from_f64(-2.25);
    println!("P8: {a} * {b} = {}", a * b);
    assert_eq!((a * b).to_f64(), -3.375);

    // exact accumulation through the quire
    let mut q = Quire::new(P16::FMT);
    for _ in 0..1000 {
        q.mac(P16::from_f64(0.125).word() as u64,
              P16::from_f64(0.5).word() as u64);
    }
    println!("quire: 1000 x 0.125*0.5 = {}",
             spade::posit::to_f64(q.to_posit(), P16::FMT));

    // --- 2. the SIMD engine --------------------------------------------
    let mode = Mode::P8x4;
    let fmt = mode.format();
    let mut eng = MacEngine::new(mode);
    let x = pack_lanes(&(1..=4).map(|i| spade::posit::from_f64(i as f64,
        fmt)).collect::<Vec<_>>(), mode);
    let y = pack_lanes(&vec![spade::posit::from_f64(2.0, fmt); 4], mode);
    eng.mac(x, y, true);
    let out = eng.read();
    println!("SIMD P8x4: [1,2,3,4] * 2 = {:?}",
             (0..4).map(|i| spade::posit::to_f64(
                 spade::engine::lane_extract(out, mode, i), fmt))
                 .collect::<Vec<_>>());
    println!("engine activity: {:?}", eng.activity());

    // --- 3. a trained model under posit inference ----------------------
    let model = Model::load("lenet5")?;
    let ds = spade::data::Dataset::load_artifact("mnist_syn", "test")?;
    let n = 64.min(ds.n);
    let (pix, labels) = ds.batch(0, n);
    let x = Tensor::from_vec(&[n, ds.h, ds.w, ds.c], pix);

    for prec in [Precision::F32, Precision::Posit(Mode::P16x2),
                 Precision::Posit(Mode::P8x4)] {
        let backend = if prec == Precision::F32 { Backend::F32 }
                      else { Backend::Posit };
        let (logits, stats) = nn::exec::forward(&model, &x, prec,
                                                backend)?;
        let acc = nn::exec::accuracy(&logits, labels);
        println!("lenet5 @ {:<4}: acc {:.3} ({} MACs, {} cycles)",
                 prec.name(), acc, stats.macs, stats.cycles);
    }
    Ok(())
}
