//! **End-to-end driver** (DESIGN.md deliverable): the full stack on a
//! real small workload.
//!
//! Pipeline: build-time-trained LeNet-5 weights (JAX, `make artifacts`)
//! -> synthetic-MNIST test set -> posit inference through
//!   (a) the native functional-posit systolic path (with cycle/energy),
//!   (b) the bit-exact quire backend (sample cross-check),
//!   (c) the AOT Pallas/JAX HLO artifact executed via PJRT,
//! -> Fig. 4-style accuracy + throughput/energy report.
//!
//! Run: `cargo run --release --example mnist_e2e [-- --limit 300]`

use anyhow::Result;

use spade::data::Dataset;
use spade::engine::Mode;
use spade::nn::{self, Backend, Model, Precision, Tensor};
use spade::runtime::Runtime;
use spade::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let limit: usize = args.num_or("limit", 300);

    println!("=== SPADE end-to-end: LeNet-5 on synthetic MNIST ===\n");
    let model = Model::load("lenet5")?;
    let ds = Dataset::load_artifact("mnist_syn", "test")?;
    let n = limit.min(ds.n);
    let (pix, labels) = ds.batch(0, n);
    let x = Tensor::from_vec(&[n, ds.h, ds.w, ds.c], pix.clone());
    println!("model: {} MAC layers, {} MACs/image; test set: {n} images\n",
             model.spec.mac_layers(),
             model.spec.layer_macs().iter().sum::<u64>());

    // (a) native posit inference across precisions
    println!("-- native systolic (functional posit, 8x8 PE dataflow) --");
    let mut f32_acc = 0.0;
    for prec in Precision::ALL {
        let backend = if prec == Precision::F32 { Backend::F32 }
                      else { Backend::Posit };
        let t0 = std::time::Instant::now();
        let (logits, stats) = nn::exec::forward(&model, &x, prec,
                                                backend)?;
        let acc = nn::exec::accuracy(&logits, labels);
        if prec == Precision::F32 {
            f32_acc = acc;
            println!("  {:<4} acc {acc:.4}   (host f32 reference, \
                      {:.2}s)", prec.name(),
                     t0.elapsed().as_secs_f64());
        } else {
            let modeled_us = stats.cycles as f64 / 1.38e9 * 1e6;
            println!("  {:<4} acc {acc:.4}   {:>11} cycles = {:.0} us \
                      @1.38GHz, {:.1} uJ   ({:.2}s sim)",
                     prec.name(), stats.cycles, modeled_us,
                     stats.energy_pj / 1e6, t0.elapsed().as_secs_f64());
        }
    }

    // (b) bit-exact quire cross-check on a sample
    println!("\n-- bit-exact quire backend cross-check (16 images) --");
    let (spix, slab) = ds.batch(0, 16);
    let xs = Tensor::from_vec(&[16, ds.h, ds.w, ds.c], spix);
    for mode in [Mode::P8x4, Mode::P16x2] {
        let (fast, _) = nn::exec::forward(&model, &xs,
                                          Precision::Posit(mode),
                                          Backend::Posit)?;
        let (exact, _) = nn::exec::forward(&model, &xs,
                                           Precision::Posit(mode),
                                           Backend::PositExact)?;
        assert_eq!(fast.data, exact.data);
        println!("  {mode:?}: functional == bit-exact ({} logits), acc \
                  {:.3}", fast.len(),
                 nn::exec::accuracy(&exact, slab));
    }

    // (c) the AOT Pallas/JAX artifact through PJRT
    println!("\n-- PJRT path (AOT jax+pallas HLO, python-free) --");
    let rt = Runtime::new()?;
    for tag in ["f32", "p32", "p16", "p8"] {
        let exe = rt.load(&format!("lenet5_{tag}_b32"), &model.params)?;
        let mut hits = 0usize;
        let mut count = 0usize;
        let t0 = std::time::Instant::now();
        let per = ds.h * ds.w * ds.c;
        for start in (0..n).step_by(32) {
            if start + 32 > n {
                break;
            }
            let batch = &pix[start * per..(start + 32) * per];
            let out = exe.run(batch)?;
            for i in 0..32 {
                let row = &out[i * 10..(i + 1) * 10];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                hits += (pred == labels[start + i] as usize) as usize;
                count += 1;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        println!("  lenet5_{tag:<4} acc {:.4}  ({count} imgs, {:.0} \
                  img/s on CPU PJRT)",
                 hits as f64 / count as f64, count as f64 / dt);
    }

    println!("\n=== claim check (Fig. 4): posit iso-accuracy vs f32 \
              (f32 acc = {f32_acc:.4}) — see rows above ===");
    Ok(())
}
