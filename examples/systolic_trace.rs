//! Cycle-accurate systolic trace: drive the Cheshire-style controller
//! command by command through a small GEMM in every MODE, printing the
//! per-tile cycle/memory/energy accounting and validating against the
//! functional path.
//!
//! Run: `cargo run --release --example systolic_trace [-- --m 8 --k 24
//!       --n 16]`

use anyhow::Result;

use spade::engine::Mode;
use spade::systolic::{ArrayConfig, Command, Controller, Response,
                      SystolicGemm};
use spade::util::{Args, SplitMix64};

fn main() -> Result<()> {
    let args = Args::from_env();
    let m: usize = args.num_or("m", 8);
    let k: usize = args.num_or("k", 24);
    let n: usize = args.num_or("n", 16);
    let mut rng = SplitMix64::new(42);
    let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();

    println!("systolic trace: {m}x{k}x{n} GEMM on a 4x2 PE array\n");
    for mode in Mode::ALL {
        let cfg = ArrayConfig { rows: 4, cols: 2, mode };
        println!("== MODE {mode:?}: {} lanes/PE, tile covers {}x{} \
                  outputs ==", mode.lanes(), cfg.rows, cfg.out_cols());

        // command-level walk of the first tile
        let mut ctl = Controller::new(cfg.rows, cfg.cols, mode);
        let oc = cfg.out_cols();
        let mut at = vec![0.0; cfg.rows * k];
        for r in 0..cfg.rows.min(m) {
            at[r * k..(r + 1) * k].copy_from_slice(&a[r * k..(r + 1) * k]);
        }
        let mut bt = vec![0.0; k * oc];
        for kk in 0..k {
            for c in 0..oc.min(n) {
                bt[kk * oc + c] = b[kk * n + c];
            }
        }
        ctl.execute(Command::LoadA { data: at, k });
        println!("  LOAD_A   -> bank A writes={}", ctl.bank_a.stats.writes);
        ctl.execute(Command::LoadB { data: bt, k });
        println!("  LOAD_B   -> bank B writes={}", ctl.bank_b.stats.writes);
        ctl.execute(Command::Compute);
        println!("  COMPUTE  -> {} cycles, {} lane-MACs",
                 ctl.array.cycles, ctl.array.total_macs());
        if let Response::Tile(t) = ctl.execute(Command::Drain) {
            println!("  DRAIN    -> {} results, first row: {:?}",
                     t.len(),
                     &t[..4.min(t.len())].iter()
                         .map(|v| format!("{v:.3}"))
                         .collect::<Vec<_>>());
        }

        // full GEMM: cycle-accurate vs functional
        let g = SystolicGemm::new(cfg);
        let (fast, fs) = g.run(&a, &b, m, k, n);
        let (slow, ss) = g.run_cycle_accurate(&a, &b, m, k, n);
        let bitexact = fast == slow;
        println!("  full GEMM: {} cycles (formula {}), {} MACs, {:.1} \
                  nJ, fast==cycle-accurate: {bitexact}",
                 ss.cycles, fs.cycles, ss.macs,
                 ss.total_energy_pj() / 1e3);
        if mode == Mode::P32x1 && !bitexact {
            println!("  (P32 fast path uses the f64 quire proxy — \
                      bit-level check lives in the P8/P16 modes)");
        }
        println!();
    }
    Ok(())
}
