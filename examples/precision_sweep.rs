//! Layer-wise precision heterogeneity study — the paper's §II-A
//! motivation quantified: sweep per-layer precision policies on a
//! trained model and chart the accuracy / energy / cycles frontier.
//!
//! Policies swept: uniform P8/P16/P32, "first-k layers at P8, rest at
//! P16/P32" ladders, and the all-but-classifier-low policy.
//!
//! Run: `cargo run --release --example precision_sweep
//!       [-- --model lenet5 --limit 200]`

use anyhow::Result;

use spade::data::Dataset;
use spade::engine::Mode;
use spade::nn::{self, Backend, Model, Precision, Tensor};
use spade::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model_name = args.get_or("model", "lenet5");
    let limit: usize = args.num_or("limit", 200);

    let model = Model::load(&model_name)?;
    let ds = Dataset::load_artifact(&model.spec.dataset, "test")?;
    let n = limit.min(ds.n);
    let (pix, labels) = ds.batch(0, n);
    let x = Tensor::from_vec(&[n, ds.h, ds.w, ds.c], pix);
    let layers = model.spec.mac_layers();

    println!("precision sweep: {model_name} ({layers} MAC layers, {n} \
              images)\n");
    let (f32_logits, _) =
        nn::exec::forward(&model, &x, Precision::F32, Backend::F32)?;
    let f32_acc = nn::exec::accuracy(&f32_logits, labels);
    println!("f32 baseline accuracy: {f32_acc:.4}\n");
    println!("{:<28} {:>8} {:>12} {:>12} {:>10}", "policy", "acc",
             "cycles", "energy(uJ)", "vs P32");

    let p8 = Precision::Posit(Mode::P8x4);
    let p16 = Precision::Posit(Mode::P16x2);
    let p32 = Precision::Posit(Mode::P32x1);

    let mut policies: Vec<(String, Vec<Precision>)> = vec![
        ("uniform p32".into(), vec![p32; layers]),
        ("uniform p16".into(), vec![p16; layers]),
        ("uniform p8".into(), vec![p8; layers]),
    ];
    // ladder: first k layers at p8, remainder p16
    for k in 1..layers {
        let mut pol = vec![p8; layers];
        for p in pol.iter_mut().skip(k) {
            *p = p16;
        }
        policies.push((format!("p8 x{k} then p16"), pol));
    }
    // classifier-guarded: everything p8, last layer p32
    let mut pol = vec![p8; layers];
    *pol.last_mut().unwrap() = p32;
    policies.push(("p8 + p32 classifier".into(), pol));

    let mut base_cycles = 0u64;
    for (name, policy) in &policies {
        let (logits, stats) =
            nn::exec::forward_policy(&model, &x, policy, Backend::Posit)?;
        let acc = nn::exec::accuracy(&logits, labels);
        if name == "uniform p32" {
            base_cycles = stats.cycles;
        }
        println!("{:<28} {:>8.4} {:>12} {:>12.1} {:>9.2}x", name, acc,
                 stats.cycles, stats.energy_pj / 1e6,
                 base_cycles as f64 / stats.cycles as f64);
    }

    println!("\nper-layer MAC distribution:");
    for (i, m) in model.spec.layer_macs().iter().enumerate() {
        println!("  MAC layer {i}: {m} MACs/image");
    }
    println!("\nreading: early layers dominate MACs -> running them in \
              P8 mode buys most of the 4x throughput while the \
              classifier keeps higher precision (paper §II-A).");
    Ok(())
}
