//! Toolchain probe: AVX-512 integer intrinsics, `avx512f` runtime
//! detection, and `#[target_feature(enable = "avx512f")]` only
//! stabilized in Rust 1.89. The crate pins no minimum toolchain, so
//! the zmm gather body is compiled conditionally: this script parses
//! `rustc --version` and emits `spade_avx512` when the compiler is new
//! enough. On older toolchains the body simply does not exist —
//! `kernel::isa` then reports AVX-512 unavailable and the forced-body
//! test names it as skipped.

use std::env;
use std::process::Command;

fn rustc_minor() -> Option<(u32, u32)> {
    let rustc = env::var("RUSTC").unwrap_or_else(|_| "rustc".into());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.91.0-nightly (abc 2026-01-01)" → ["1", "91", ...]
    let ver = text.split_whitespace().nth(1)?;
    let ver = ver.split('-').next()?;
    let mut parts = ver.split('.');
    let major = parts.next()?.parse().ok()?;
    let minor = parts.next()?.parse().ok()?;
    Some((major, minor))
}

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    println!("cargo:rustc-check-cfg=cfg(spade_avx512)");
    if let Some((major, minor)) = rustc_minor() {
        if major > 1 || (major == 1 && minor >= 89) {
            println!("cargo:rustc-cfg=spade_avx512");
        }
    }
}
