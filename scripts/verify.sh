#!/usr/bin/env bash
# Tier-1 verify + perf gate for the SPADE reproduction.
#
#   build (release) -> tests -> hotpath bench (writes BENCH_hotpath.json)
#   -> fmt / clippy (advisory only: the seed tree predates both gates).
#
# Usage: scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo bench --bench hotpath =="
cargo bench --bench hotpath

echo "== cargo fmt --check (advisory) =="
cargo fmt --check || echo "(fmt drift — advisory only)"

echo "== cargo clippy -D warnings (advisory) =="
cargo clippy --all-targets -- -D warnings \
  || echo "(clippy findings — advisory only)"

echo "verify: OK"
