#!/usr/bin/env bash
# Tier-1 verify + perf + docs gate for the SPADE reproduction.
#
#   build (release) -> tests -> hotpath bench (writes BENCH_hotpath.json)
#   -> docs gate (rustdoc warnings are errors)
#   -> fmt / clippy (advisory only: the seed tree predates both gates).
#
# Usage: scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
  echo "verify: cargo not found on PATH — nothing was built or tested." >&2
  echo "verify: BENCH_hotpath.json stays a placeholder until" >&2
  echo "        'cargo bench --bench hotpath' runs on a machine with the" >&2
  echo "        Rust toolchain (schema: README.md, section 'Reading" >&2
  echo "        BENCH_hotpath.json')." >&2
  exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo bench --bench hotpath =="
cargo bench --bench hotpath

echo "== cargo doc --no-deps (docs gate: warnings are errors) =="
RUSTDOCFLAGS="${RUSTDOCFLAGS:-} -D warnings" cargo doc --no-deps

echo "== cargo fmt --check (advisory) =="
cargo fmt --check || echo "(fmt drift — advisory only)"

echo "== cargo clippy -D warnings (advisory) =="
cargo clippy --all-targets -- -D warnings \
  || echo "(clippy findings — advisory only)"

echo "verify: OK"
