#!/usr/bin/env bash
# Tier-1 verify + perf + docs gate for the SPADE reproduction.
#
#   build (release) -> tests -> hotpath bench smoke gate (quick mode,
#   writes BENCH_hotpath.json and checks the required sections)
#   -> docs gate (rustdoc warnings are errors)
#   -> fmt / clippy (advisory only: the seed tree predates both gates).
#
# Usage: scripts/verify.sh
#   SPADE_BENCH_QUICK=0 scripts/verify.sh   # full-size bench instead
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== env hygiene gate (all SPADE_* reads centralized) =="
# PR 4 contract: SPADE_* environment variables are read in exactly one
# module — rust/src/api/env.rs — and parsed once at the process edge
# (EngineConfig::from_env). Any other `env::var("SPADE_...` in the
# Rust tree fails the build; new knobs (e.g. PR 5's
# SPADE_KERNEL_AUTOTUNE) are covered automatically by the prefix
# match. Runs before the cargo gates so it works even on machines
# without a toolchain.
env_hits=$(grep -RInE 'env::var[[:space:]]*\([[:space:]]*"SPADE_' \
               --include='*.rs' rust examples \
           | grep -v '^rust/src/api/env\.rs:' || true)
if [ -n "$env_hits" ]; then
  echo "verify: SPADE_* environment reads outside rust/src/api/env.rs:" >&2
  echo "$env_hits" >&2
  echo "        route new knobs through api::env / EngineConfig::from_env." >&2
  exit 1
fi
echo "ok: SPADE_* env reads confined to rust/src/api/env.rs"

echo "== fused-pipeline gate (no interior encodes in nn::exec) =="
# PR 6 contract: the fused planar pipeline quantizes exactly once at
# the input edge (exec.rs::edge_quantize wraps DecodedPlan::from_f32)
# and materializes floats once at the output edge — no layer body may
# call the posit encoder directly. Zero `encode(` / `from_f64(`
# occurrences anywhere in exec.rs enforces that statically; like the
# env gate, this runs even without a toolchain.
exec_hits=$(grep -nE '\b(encode|from_f64)\(' rust/src/nn/exec.rs || true)
if [ -n "$exec_hits" ]; then
  echo "verify: direct posit encodes in rust/src/nn/exec.rs:" >&2
  echo "$exec_hits" >&2
  echo "        layer bodies must stay in the planar domain; only" >&2
  echo "        edge_quantize/materialize_f32 cross the boundary." >&2
  exit 1
fi
echo "ok: nn::exec has no direct posit encodes (edge-only quantization)"

echo "== serving-path gate (no unwrap/expect in supervised code) =="
# PR 8 contract: every accepted request terminates in exactly one
# typed reply, so the serving paths (coordinator + kernel pool) must
# not carry `.unwrap()` / `.expect(` outside their test modules — a
# poisoned lock or closed channel is recovered or answered typed,
# never allowed to kill a shard for a second reason. The awk prefix
# stops at the first `#[cfg(test)]` (test-module unwraps stay legal)
# and skips comment lines (docs may *name* the forbidden calls).
# Toolchain-free, like the gates above.
unwrap_hits=""
for f in rust/src/coordinator/*.rs rust/src/kernel/pool.rs; do
  hits=$(awk '/#\[cfg\(test\)\]/{exit}
              /^[[:space:]]*\/\//{next}
              {print FILENAME":"FNR": "$0}' "$f" \
         | grep -E '\.unwrap\(\)|\.expect\(' || true)
  if [ -n "$hits" ]; then
    unwrap_hits="${unwrap_hits}${hits}
"
  fi
done
if [ -n "$unwrap_hits" ]; then
  echo "verify: unwrap/expect on a supervised serving path:" >&2
  printf '%s' "$unwrap_hits" >&2
  echo "        recover (lock_recover/lock_metrics), answer typed, or" >&2
  echo "        move the assertion into the #[cfg(test)] module." >&2
  exit 1
fi
echo "ok: coordinator + kernel pool carry no unwrap/expect outside tests"

if ! command -v cargo >/dev/null 2>&1; then
  echo "verify: cargo not found on PATH — nothing was built or tested." >&2
  echo "verify: BENCH_hotpath.json stays a placeholder until" >&2
  echo "        'cargo bench --bench hotpath' runs on a machine with the" >&2
  echo "        Rust toolchain (schema: README.md, section 'Reading" >&2
  echo "        BENCH_hotpath.json')." >&2
  exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo bench --bench hotpath (smoke gate) =="
# Quick mode by default: same JSON sections, smaller shapes. Export
# SPADE_BENCH_QUICK=0 for the full-size run.
SPADE_BENCH_QUICK="${SPADE_BENCH_QUICK:-1}" cargo bench --bench hotpath

# The bench must have emitted the inner-loop, dispatch, self-tuning,
# fused-pipeline, sparse-vs-dense, and degrade-vs-reject comparison
# sections — a silent regression to the old loops (or a lost autotune/
# k-chunk/hybrid-LUT/fusion/sparse/overload measurement) would
# otherwise pass. The sparse gate wants a speedup key at three
# sparsity levels per precision; the degrade gate wants goodput and
# p99 under synthetic overload with degradation on vs off.
for key in simd_vs_scalar_gather blocked_vs_unblocked_p16 \
           steal_vs_fixed_split autotuned_vs_default \
           kchunk_vs_full_k p16_hybrid_lut_vs_exact \
           fused_vs_layerwise_p8 fused_vs_layerwise_p16 \
           fused_vs_layerwise_p32 fused_vs_layerwise_decodes_avoided \
           sparse_vs_dense_p8_d1 sparse_vs_dense_p8_d10 \
           sparse_vs_dense_p8_d50 sparse_vs_dense_p16_d1 \
           sparse_vs_dense_p16_d10 sparse_vs_dense_p16_d50 \
           sparse_vs_dense_p32_d1 sparse_vs_dense_p32_d10 \
           sparse_vs_dense_p32_d50 \
           degrade_vs_reject_goodput_on degrade_vs_reject_goodput_off \
           degrade_vs_reject_p99us_on degrade_vs_reject_p99us_off; do
  if ! grep -q "\"$key\"" BENCH_hotpath.json; then
    echo "verify: BENCH_hotpath.json is missing the '$key' section" >&2
    echo "        (did benches/hotpath.rs lose a comparison?)" >&2
    exit 1
  fi
done

echo "== cargo doc --no-deps (docs gate: warnings are errors) =="
RUSTDOCFLAGS="${RUSTDOCFLAGS:-} -D warnings" cargo doc --no-deps

echo "== cargo fmt --check (advisory) =="
cargo fmt --check || echo "(fmt drift — advisory only)"

echo "== cargo clippy -D warnings (advisory) =="
cargo clippy --all-targets -- -D warnings \
  || echo "(clippy findings — advisory only)"

echo "verify: OK"
