#!/usr/bin/env bash
# Tier-1 verify + perf + docs gate for the SPADE reproduction.
#
#   With a toolchain:  build (release) -> spade-lint (hard invariant
#   gate, writes LINT_report.json) -> tests -> hotpath bench smoke
#   gate (quick mode, writes BENCH_hotpath.json and checks the
#   required sections) -> docs gate (rustdoc warnings are errors)
#   -> fmt (advisory) -> clippy (advisory, behind an availability
#   check).
#
#   Without a toolchain: the legacy grep/awk one-liners run as a
#   toolchain-free approximation of the spade-lint invariants
#   (env-hygiene, edge-only-encode, no-unwrap), then the script
#   fails: nothing was built or tested.
#
# Usage: scripts/verify.sh
#   SPADE_BENCH_QUICK=0 scripts/verify.sh   # full-size bench instead
set -euo pipefail
cd "$(dirname "$0")/.."

# ----------------------------------------------------------------------
# Toolchain-free fallback gates. These are the original grep/awk
# contracts that spade-lint (rust/src/lint/) superseded with
# lexer-accurate rules; they remain here so a machine without cargo
# still gets a first-order invariant check before the hard failure
# below. They are strictly weaker than spade-lint: grep cannot see
# token boundaries, and the awk gate cannot apply `lint: allow`
# suppressions (it checks unwrap/expect only, which carry none).
run_fallback_gates() {
  echo "== fallback: env hygiene (all SPADE_* reads centralized) =="
  # Contract (PR 4): SPADE_* environment variables are read in exactly
  # one module — rust/src/api/env.rs. spade-lint rule: env-hygiene.
  # The lint subsystem's docs and fixtures spell the forbidden pattern
  # inside comments and string literals; grep cannot tell those from
  # code (spade-lint can — its lexer-based rule keeps those files
  # honest), so they are excluded here.
  env_hits=$(grep -RInE 'env::var[[:space:]]*\([[:space:]]*"SPADE_' \
                 --include='*.rs' rust examples \
             | grep -v '^rust/src/api/env\.rs:' \
             | grep -v '^rust/src/lint/' \
             | grep -v '^rust/src/bin/spade_lint\.rs:' \
             | grep -v '^rust/tests/lint_rules\.rs:' || true)
  if [ -n "$env_hits" ]; then
    echo "verify: SPADE_* environment reads outside rust/src/api/env.rs:" >&2
    echo "$env_hits" >&2
    echo "        route new knobs through api::env / EngineConfig::from_env." >&2
    exit 1
  fi
  echo "ok: SPADE_* env reads confined to rust/src/api/env.rs"

  echo "== fallback: fused-pipeline (no interior encodes in nn::exec) =="
  # Contract (PR 6): the fused planar pipeline quantizes exactly once
  # at the input edge. spade-lint rule: edge-only-encode.
  exec_hits=$(grep -nE '\b(encode|from_f64)\(' rust/src/nn/exec.rs || true)
  if [ -n "$exec_hits" ]; then
    echo "verify: direct posit encodes in rust/src/nn/exec.rs:" >&2
    echo "$exec_hits" >&2
    echo "        layer bodies must stay in the planar domain; only" >&2
    echo "        edge_quantize/materialize_f32 cross the boundary." >&2
    exit 1
  fi
  echo "ok: nn::exec has no direct posit encodes (edge-only quantization)"

  echo "== fallback: serving paths (no unwrap/expect outside tests) =="
  # Contract (PR 8): every accepted request terminates in exactly one
  # typed reply. spade-lint rule: no-unwrap. The awk below skips
  # #[cfg(test)] items by tracking brace depth and RESUMES scanning
  # after each one (the old prefix gate stopped at the first test
  # module, so live code placed after it escaped the check).
  unwrap_hits=""
  for f in rust/src/coordinator/*.rs rust/src/kernel/pool.rs; do
    hits=$(awk '
        skip {
          nopen = gsub(/{/, "{"); nclose = gsub(/}/, "}")
          depth += nopen - nclose
          if (!started && nopen > 0) started = 1
          if (!started && $0 ~ /;[[:space:]]*$/) skip = 0
          if (started && depth <= 0) { skip = 0; started = 0 }
          next
        }
        /^[[:space:]]*\/\//{next}
        /#\[cfg\(test\)\]/ { skip = 1; depth = 0; started = 0; next }
        {print FILENAME":"FNR": "$0}' "$f" \
           | grep -E '\.unwrap\(\)|\.expect\(' || true)
    if [ -n "$hits" ]; then
      unwrap_hits="${unwrap_hits}${hits}
"
    fi
  done
  if [ -n "$unwrap_hits" ]; then
    echo "verify: unwrap/expect on a supervised serving path:" >&2
    printf '%s' "$unwrap_hits" >&2
    echo "        recover (lock_recover/lock_metrics), answer typed, or" >&2
    echo "        move the assertion into the #[cfg(test)] module." >&2
    exit 1
  fi
  echo "ok: coordinator + kernel pool carry no unwrap/expect outside tests"
}

if ! command -v cargo >/dev/null 2>&1; then
  run_fallback_gates
  echo "verify: cargo not found on PATH — nothing was built or tested." >&2
  echo "verify: the grep/awk gates above are only the toolchain-free" >&2
  echo "        approximation; the full invariant pass is" >&2
  echo "        'cargo run --release --bin spade-lint' (see README," >&2
  echo "        section 'Static analysis: spade-lint')." >&2
  echo "verify: BENCH_hotpath.json stays a placeholder until" >&2
  echo "        'cargo bench --bench hotpath' runs on a machine with the" >&2
  echo "        Rust toolchain (schema: README.md, section 'Reading" >&2
  echo "        BENCH_hotpath.json')." >&2
  exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== spade-lint (hard invariant gate, writes LINT_report.json) =="
# Lexer-accurate superset of the legacy grep gates: env-hygiene,
# edge-only-encode, no-unwrap, unsafe-audit, lock-order, spawn-audit,
# counter-coverage. Exits nonzero on any unsuppressed finding; every
# `lint: allow` must carry a justification. Report schema:
# LINT_report.json, `spade-lint-v1` (see README).
cargo run --release --bin spade-lint

echo "== cargo test -q =="
cargo test -q

echo "== cargo bench --bench hotpath (smoke gate) =="
# Quick mode by default: same JSON sections, smaller shapes. Export
# SPADE_BENCH_QUICK=0 for the full-size run.
SPADE_BENCH_QUICK="${SPADE_BENCH_QUICK:-1}" cargo bench --bench hotpath

# The bench must have emitted the inner-loop, dispatch, self-tuning,
# fused-pipeline, sparse-vs-dense, and degrade-vs-reject comparison
# sections — a silent regression to the old loops (or a lost autotune/
# k-chunk/hybrid-LUT/fusion/sparse/overload measurement) would
# otherwise pass. The sparse gate wants a speedup key at three
# sparsity levels per precision; the degrade gate wants goodput and
# p99 under synthetic overload with degradation on vs off.
for key in simd_vs_scalar_gather blocked_vs_unblocked_p16 \
           steal_vs_fixed_split autotuned_vs_default \
           kchunk_vs_full_k p16_hybrid_lut_vs_exact \
           fused_vs_layerwise_p8 fused_vs_layerwise_p16 \
           fused_vs_layerwise_p32 fused_vs_layerwise_decodes_avoided \
           sparse_vs_dense_p8_d1 sparse_vs_dense_p8_d10 \
           sparse_vs_dense_p8_d50 sparse_vs_dense_p16_d1 \
           sparse_vs_dense_p16_d10 sparse_vs_dense_p16_d50 \
           sparse_vs_dense_p32_d1 sparse_vs_dense_p32_d10 \
           sparse_vs_dense_p32_d50 \
           degrade_vs_reject_goodput_on degrade_vs_reject_goodput_off \
           degrade_vs_reject_p99us_on degrade_vs_reject_p99us_off \
           isa_body_p8_portable isa_body_matrix_bodies \
           tuned_persist_cold_vs_warm; do
  if ! grep -q "\"$key\"" BENCH_hotpath.json; then
    echo "verify: BENCH_hotpath.json is missing the '$key' section" >&2
    echo "        (did benches/hotpath.rs lose a comparison?)" >&2
    exit 1
  fi
done

echo "== cargo doc --no-deps (docs gate: warnings are errors) =="
RUSTDOCFLAGS="${RUSTDOCFLAGS:-} -D warnings" cargo doc --no-deps

echo "== cargo fmt --check (advisory) =="
if cargo fmt --version >/dev/null 2>&1; then
  cargo fmt --check || echo "(fmt drift — advisory only)"
else
  echo "(rustfmt not installed — skipped)"
fi

echo "== cargo clippy -D warnings (advisory) =="
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings \
    || echo "(clippy findings — advisory only)"
else
  echo "(clippy not installed — skipped)"
fi

echo "verify: OK"
